//! Integration: the REAL multi-process cluster. `apple-moe launch`
//! spawns one OS process per node, meshed over loopback TCP
//! (`network::tcp`), and must generate byte-identical token streams to
//! the in-process mpsc fabric for both topologies — the acceptance
//! criterion for the socket transport subsystem. The node processes now
//! run the iteration-level scheduler (concurrency 2 by default), so
//! this also asserts that interleaved serving over real sockets stays
//! token-identical to serial in-process serving. Skips politely until
//! `make artifacts` has run (like every live-cluster test).

// Test code: a panic is the failure report (see clippy.toml).
#![allow(clippy::unwrap_used)]

use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::time::{Duration, Instant};

use apple_moe::cluster::live::{LiveCluster, LiveConfig};
use apple_moe::config::{Balancing, ClusterHosts, Topology};
use apple_moe::engine::api::{Engine, TokenEvent};
use apple_moe::engine::scheduler::SchedPolicy;
use apple_moe::engine::{RemoteEngine, Request};

const N_REQUESTS: usize = 2;
const PROMPT_TOKENS: usize = 4;
const GEN_TOKENS: usize = 6;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.txt").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: run `make artifacts` first");
        None
    }
}

/// The same request stream `apple-moe node` derives from its flags
/// (including the per-request seed derivation, seed ^ id).
fn requests() -> Vec<Request> {
    (0..N_REQUESTS)
        .map(|i| {
            let mut r = Request::synthetic(i as u64, PROMPT_TOKENS, 512, GEN_TOKENS);
            r.sampling.seed ^= i as u64;
            r
        })
        .collect()
}

/// Token streams from the threaded in-process cluster, served strictly
/// serially (the reference the interleaved runs must reproduce).
fn in_process_tokens(dir: &Path, topology: Topology, balancing: Balancing) -> Vec<Vec<u32>> {
    let mut cfg = LiveConfig::new(dir.to_path_buf(), 2);
    cfg.topology = topology;
    cfg.balancing = balancing;
    cfg.max_active = 1;
    cfg.policy = SchedPolicy::RunToCompletion;
    let cluster = LiveCluster::start(cfg).unwrap();
    let out = requests()
        .into_iter()
        .map(|req| cluster.submit(req).unwrap().join().unwrap().generated)
        .collect();
    cluster.shutdown();
    out
}

/// Token streams from 2 real node processes via `apple-moe launch`
/// (which defaults to concurrency 2: the requests interleave).
fn multi_process_tokens(dir: &Path, topology: &str, balancing: &str) -> Vec<Vec<u32>> {
    let out_path = std::env::temp_dir().join(format!(
        "apple-moe-test-{}-{topology}.tokens",
        std::process::id()
    ));
    let _ = std::fs::remove_file(&out_path);
    let n_requests = N_REQUESTS.to_string();
    let prompt = PROMPT_TOKENS.to_string();
    let gen = GEN_TOKENS.to_string();
    let status = Command::new(env!("CARGO_BIN_EXE_apple-moe"))
        .args([
            "launch",
            "--nodes",
            "2",
            "--topology",
            topology,
            "--balancing",
            balancing,
            "--requests",
            n_requests.as_str(),
            "--prompt-tokens",
            prompt.as_str(),
            "--gen-tokens",
            gen.as_str(),
            "--concurrency",
            "2",
            "--recv-timeout-secs",
            "120",
            "--artifacts",
        ])
        .arg(dir)
        .arg("--out")
        .arg(&out_path)
        .status()
        .expect("spawning apple-moe launch");
    assert!(status.success(), "launch ({topology}) exited with {status}");
    let text = std::fs::read_to_string(&out_path).expect("reading --out token file");
    let _ = std::fs::remove_file(&out_path);
    text.lines()
        .map(|l| {
            l.split_whitespace()
                .map(|t| t.parse::<u32>().expect("token id"))
                .collect()
        })
        .collect()
}

#[test]
fn launch_decentralized_matches_in_process_fabric() {
    let Some(dir) = artifacts_dir() else { return };
    let want = in_process_tokens(&dir, Topology::Decentralized, Balancing::RouterAided);
    let got = multi_process_tokens(&dir, "decentralized", "router-aided");
    assert_eq!(got.len(), N_REQUESTS);
    assert!(got.iter().all(|g| g.len() == GEN_TOKENS));
    assert_eq!(got, want, "TCP multi-process tokens diverge from in-process fabric");
}

#[test]
fn launch_centralized_matches_in_process_fabric() {
    let Some(dir) = artifacts_dir() else { return };
    let want = in_process_tokens(&dir, Topology::Centralized, Balancing::SelectedOnly);
    let got = multi_process_tokens(&dir, "centralized", "selected-only");
    assert_eq!(got, want, "TCP multi-process tokens diverge from in-process fabric");
}

/// `run_node` + a loopback TCP fabric inside one process: the same
/// equivalence without process spawning (finer-grained failure mode,
/// and it exercises `network::tcp` under cargo's default test runner).
/// Node 0 schedules both requests concurrently (round-robin, the
/// `req_tag` per-request demux on the wire); followers receive the
/// workload over the admission broadcast — they are handed NO requests.
#[test]
fn tcp_fabric_in_process_nodes_match_mpsc_fabric() {
    let Some(dir) = artifacts_dir() else { return };
    let want = in_process_tokens(&dir, Topology::Decentralized, Balancing::RouterAided);

    let eps = apple_moe::network::tcp::loopback_fabric(2).unwrap();
    let reqs = requests();
    let mut handles = Vec::new();
    for ep in eps {
        let mut cfg = LiveConfig::new(dir.clone(), 2);
        cfg.topology = Topology::Decentralized;
        cfg.balancing = Balancing::RouterAided;
        cfg.max_active = 2;
        cfg.policy = SchedPolicy::RoundRobin;
        // Followers get an empty request list: admissions ride the
        // control plane.
        let reqs = if ep.node() == 0 { reqs.clone() } else { Vec::new() };
        handles.push(std::thread::spawn(move || {
            apple_moe::cluster::live::run_node(&cfg, ep, &reqs).unwrap()
        }));
    }
    let results: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    let got: Vec<Vec<u32>> = results[0].iter().map(|r| r.generated.clone()).collect();
    assert_eq!(got, want, "run_node over TCP diverges from LiveCluster");
    assert!(results[1].is_empty(), "followers return no results");
    // Wire accounting flowed into the metrics: the decentralized
    // protocol exchanges one partial per peer per layer per token.
    let decode = &results[0][0].metrics.decode;
    assert!(decode.net_bytes > 0, "no wire traffic metered");
    assert!(decode.net_msgs > 0);
    // And the serving surface is metered on the TCP path too.
    assert!(results[0][0].metrics.latency_ns > 0);
}

// ---------------- remote serving protocol ----------------

/// Kill-on-drop guard so a failing assertion can't leak daemon
/// processes into the test runner.
struct Daemon(Child);

impl Drop for Daemon {
    fn drop(&mut self) {
        let _ = self.0.kill();
        let _ = self.0.wait();
    }
}

fn free_port() -> u16 {
    std::net::TcpListener::bind("127.0.0.1:0")
        .unwrap()
        .local_addr()
        .unwrap()
        .port()
}

/// `apple-moe launch --client-port ...` with no local requests: a pure
/// remote-serving daemon cluster (2 OS processes over loopback TCP).
fn spawn_daemon(dir: &Path, topology: &str, balancing: &str, concurrency: usize, port: u16) -> Daemon {
    let child = Command::new(env!("CARGO_BIN_EXE_apple-moe"))
        .args([
            "launch",
            "--nodes",
            "2",
            "--topology",
            topology,
            "--balancing",
            balancing,
            "--requests",
            "0",
            "--concurrency",
            &concurrency.to_string(),
            "--client-port",
            &port.to_string(),
            "--recv-timeout-secs",
            "120",
            "--artifacts",
        ])
        .arg(dir)
        .stdout(Stdio::null())
        .spawn()
        .expect("spawning apple-moe launch --client-port");
    Daemon(child)
}

/// Dial the daemon's client port, retrying while its node processes
/// compile their runtimes.
fn connect_retry(port: u16, deadline: Duration) -> RemoteEngine {
    let addr = format!("127.0.0.1:{port}");
    let t0 = Instant::now();
    loop {
        match RemoteEngine::connect(&addr) {
            Ok(e) => return e,
            Err(e) => {
                assert!(
                    t0.elapsed() < deadline,
                    "daemon never started serving clients on {addr}: {e:#}"
                );
                std::thread::sleep(Duration::from_millis(200));
            }
        }
    }
}

/// Submit over the wire, capturing both the streamed tokens and the
/// joined result (they must agree).
fn remote_generate(eng: &mut RemoteEngine, req: Request) -> Vec<u32> {
    let handle = eng.submit(req).unwrap();
    let mut streamed = Vec::new();
    let result = loop {
        match handle.next_event().expect("stream ended early") {
            TokenEvent::Token { id, .. } => streamed.push(id),
            TokenEvent::Done { result } => break result,
            TokenEvent::Failed { error, .. } => panic!("remote request failed: {error}"),
            _ => {}
        }
    };
    assert_eq!(streamed, result.generated, "streamed tokens diverge from joined result");
    assert!(result.metrics.latency_ns > 0, "serving metrics crossed the wire");
    result.generated
}

/// The acceptance criterion for the remote serving protocol: a remote
/// client against a `launch`-spawned daemon streams tokens identical
/// to the in-process `Engine::submit` path, on both topologies.
fn remote_matches_in_process(topology: Topology, topo: &str, balancing: Balancing, bal: &str) {
    let Some(dir) = artifacts_dir() else { return };
    let want = in_process_tokens(&dir, topology, balancing);
    let port = free_port();
    let mut daemon = spawn_daemon(&dir, topo, bal, 2, port);
    let mut eng = connect_retry(port, Duration::from_secs(300));
    let got: Vec<Vec<u32>> =
        requests().into_iter().map(|r| remote_generate(&mut eng, r)).collect();
    assert_eq!(got, want, "remote client tokens diverge from in-process fabric ({topo})");
    let link = eng.stats();
    assert!(link.sent_msgs >= N_REQUESTS as u64, "client sends unmetered");
    assert!(link.recv_bytes > 0, "client receives unmetered");
    // Administrative shutdown: the daemon cluster drains and exits 0.
    eng.shutdown_server().unwrap();
    drop(eng);
    let t0 = Instant::now();
    loop {
        if let Some(status) = daemon.0.try_wait().unwrap() {
            assert!(status.success(), "daemon exited with {status}");
            break;
        }
        assert!(
            t0.elapsed() < Duration::from_secs(60),
            "daemon did not exit after client --shutdown"
        );
        std::thread::sleep(Duration::from_millis(100));
    }
}

#[test]
fn remote_client_matches_in_process_decentralized() {
    remote_matches_in_process(
        Topology::Decentralized,
        "decentralized",
        Balancing::RouterAided,
        "router-aided",
    );
}

#[test]
fn remote_client_matches_in_process_centralized() {
    remote_matches_in_process(
        Topology::Centralized,
        "centralized",
        Balancing::SelectedOnly,
        "selected-only",
    );
}

/// Dead-client slot reclamation end to end: with `--concurrency 1`, a
/// client that vanishes mid-decode must free the single slot (its
/// request self-cancels at the next sweep) so a second client's
/// request still completes — with tokens identical to the in-process
/// reference.
#[test]
fn vanished_remote_client_frees_its_slot() {
    let Some(dir) = artifacts_dir() else { return };
    let want = in_process_tokens(&dir, Topology::Decentralized, Balancing::RouterAided);
    let port = free_port();
    let _daemon = spawn_daemon(&dir, "decentralized", "router-aided", 1, port);

    // Client A grabs the only slot with a long request and dies after
    // the first streamed token.
    let mut a = connect_retry(port, Duration::from_secs(300));
    let mut long = Request::synthetic(777, PROMPT_TOKENS, 512, 512);
    long.sampling.seed ^= 777;
    let ha = a.submit(long).unwrap();
    loop {
        match ha.next_event().expect("stream ended early") {
            TokenEvent::Token { .. } => break,
            TokenEvent::Failed { error, .. } => panic!("long request failed: {error}"),
            _ => {}
        }
    }
    drop(ha);
    drop(a); // the socket closes abruptly: no Cancel frame, no goodbye

    // Client B must still be served, token-identically.
    let mut b = connect_retry(port, Duration::from_secs(60));
    let got = remote_generate(&mut b, requests().remove(0));
    assert_eq!(got, want[0], "second client's tokens diverge after a client death");
    b.shutdown_server().unwrap();
}

/// Follower liveness end to end (3 real node processes): killing node 0
/// mid-idle must make BOTH followers exit promptly with the named
/// leader-lost error, instead of idling until all peers hang up.
#[test]
fn followers_exit_when_leader_process_dies_mid_idle() {
    let Some(dir) = artifacts_dir() else { return };
    let n = 3;
    // Liveness bound for the test cluster. Also bounds each follower's
    // FIRST wait (while the leader may still be compiling its runtime),
    // so it must comfortably cover node-to-node startup skew.
    let recv_timeout_secs = 20u64;
    let mut hosts = Vec::new();
    for _ in 0..n {
        hosts.push(format!("127.0.0.1:{}", free_port()));
    }
    let cfg = ClusterHosts {
        hosts,
        recv_timeout: Duration::from_secs(recv_timeout_secs),
        connect_timeout: Duration::from_secs(120),
    };
    let hosts_path = std::env::temp_dir()
        .join(format!("apple-moe-liveness-{}.toml", std::process::id()));
    std::fs::write(&hosts_path, cfg.render()).unwrap();

    let client_port = free_port();
    let spawn_node = |id: usize| -> Daemon {
        let mut cmd = Command::new(env!("CARGO_BIN_EXE_apple-moe"));
        cmd.args(["node", "--id", &id.to_string(), "--cluster"])
            .arg(&hosts_path)
            .args(["--requests", "0", "--artifacts"])
            .arg(&dir)
            .stdout(Stdio::null())
            .stderr(Stdio::piped());
        if id == 0 {
            // The client port keeps node 0 alive (a daemon idling for
            // remote clients) so there is a mid-idle leader to kill.
            cmd.args(["--client-port", &client_port.to_string()]);
        }
        Daemon(cmd.spawn().expect("spawning node"))
    };
    let mut leader = spawn_node(0);
    let mut followers = vec![spawn_node(1), spawn_node(2)];

    // The cluster is fully up (mesh + runtimes + serve loops) once the
    // client port answers a handshake.
    let eng = connect_retry(client_port, Duration::from_secs(300));
    drop(eng);

    let _ = leader.0.kill();
    let _ = leader.0.wait();
    let t_kill = Instant::now();
    let bound = Duration::from_secs(recv_timeout_secs) + Duration::from_secs(25);
    for f in &mut followers {
        loop {
            if let Some(status) = f.0.try_wait().unwrap() {
                // Followers exit non-zero, naming the lost leader.
                assert!(!status.success(), "follower exited cleanly after leader death");
                break;
            }
            assert!(
                t_kill.elapsed() < bound,
                "follower still running {bound:?} after leader death"
            );
            std::thread::sleep(Duration::from_millis(100));
        }
    }
    let mut stderr = String::new();
    for f in &mut followers {
        use std::io::Read;
        if let Some(e) = f.0.stderr.as_mut() {
            let _ = e.read_to_string(&mut stderr);
        }
    }
    assert!(
        stderr.contains("leader silent"),
        "follower exit did not name the lost leader:\n{stderr}"
    );
    let _ = std::fs::remove_file(&hosts_path);
}

/// `serve --transport tcp --json` end-to-end through the binary: the
/// machine-readable report CI tracks must parse (loosely validated here
/// by checking its key fields; CI runs a real JSON parser over it).
#[test]
fn serve_json_over_tcp_transport_emits_report() {
    let Some(dir) = artifacts_dir() else { return };
    let out = Command::new(env!("CARGO_BIN_EXE_apple-moe"))
        .args([
            "serve",
            "--nodes",
            "2",
            "--requests",
            "3",
            "--concurrency",
            "2",
            "--prompt-tokens",
            "4",
            "--gen-tokens",
            "5",
            "--transport",
            "tcp",
            "--json",
            "--artifacts",
        ])
        .arg(&dir)
        .output()
        .expect("spawning apple-moe serve");
    assert!(
        out.status.success(),
        "serve --json failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let text = String::from_utf8(out.stdout).expect("utf8 report");
    let line = text.trim();
    assert!(line.starts_with('{') && line.ends_with('}'), "not a JSON object: {line}");
    for key in [
        "\"requests\":[",
        "\"ttft_s\":",
        "\"queueing_s\":",
        "\"latency_s\":",
        "\"decode_tps\":",
        "\"net_bytes\":",
        "\"concurrency\":2",
        "\"aggregate_tps\":",
    ] {
        assert!(line.contains(key), "missing {key} in {line}");
    }
}
