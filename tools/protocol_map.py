#!/usr/bin/env python3
"""Offline mirror of `cargo xtask protocol` (rust/xtask/src/protocol.rs).

Extracts the fabric communication graph from rust/src — every
send/broadcast vs recv_tag/gather site per PHASE_* tag, every OP_*
emit vs dispatch site — checks the four protocol-flow failure classes
(orphan send, dead channel, unbounded blocking recv, unmatched opcode)
and regenerates (--bless) or drift-checks rust/protocol.map without a
Rust toolchain. The algorithm mirrors rust/xtask/src/lexer.rs and
rust/xtask/src/protocol.rs — any change on either side must land on
the other, and `cargo xtask protocol` is the source of truth when they
disagree.

Usage:
    python3 tools/protocol_map.py            # verify, exit 1 on findings/drift
    python3 tools/protocol_map.py --bless    # rewrite rust/protocol.map
"""

import os
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
RUST = os.path.join(REPO, "rust")
MAP = os.path.join(RUST, "protocol.map")

IDENT, LITERAL, LIFETIME, PUNCT = "ident", "literal", "lifetime", "punct"


def is_ident_start(c):
    return c.isascii() and (c.isalpha() or c == "_")


def is_ident_cont(c):
    return c.isascii() and (c.isalnum() or c == "_")


def scan_allow(comment, line, allows):
    marker = "xtask: allow("
    at = comment.find(marker)
    if at >= 0:
        rest = comment[at + len(marker):]
        end = rest.find(")")
        if end >= 0:
            allows.append((line, rest[:end].strip()))


def lex(src):
    """Tokenize like rust/xtask/src/lexer.rs, tracking line numbers and
    collecting `// xtask: allow(<name>): why` directives."""
    b = src
    n = len(b)
    toks = []
    allows = []
    i = 0
    line = 1
    while i < n:
        c = b[i]
        if c == "\n":
            line += 1
            i += 1
            continue
        if c.isspace():
            i += 1
            continue
        if c == "/" and i + 1 < n and b[i + 1] == "/":
            start = i
            while i < n and b[i] != "\n":
                i += 1
            scan_allow(b[start:i], line, allows)
            continue
        if c == "/" and i + 1 < n and b[i + 1] == "*":
            start = i
            start_line = line
            depth = 1
            i += 2
            while i < n and depth > 0:
                if b[i] == "\n":
                    line += 1
                if b[i] == "/" and i + 1 < n and b[i + 1] == "*":
                    depth += 1
                    i += 2
                elif b[i] == "*" and i + 1 < n and b[i + 1] == "/":
                    depth -= 1
                    i += 2
                else:
                    i += 1
            scan_allow(b[start:i], start_line, allows)
            continue
        if c == "r" or (c == "b" and i + 1 < n and b[i + 1] == "r"):
            j = i + (2 if c == "b" else 1)
            hashes = 0
            while j < n and b[j] == "#":
                hashes += 1
                j += 1
            raw_ident = i + 2 < n and b[i + 1] == "#" and is_ident_start(b[i + 2])
            if j < n and b[j] == '"' and not (hashes > 0 and c == "r" and raw_ident):
                j += 1
                while j < n:
                    if b[j] == "\n":
                        line += 1
                    if b[j] == '"' and all(
                        j + k < n and b[j + k] == "#" for k in range(1, hashes + 1)
                    ):
                        j += 1 + hashes
                        break
                    j += 1
                toks.append((b[i:min(j, n)], LITERAL, line))
                i = j
                continue
            if hashes == 1 and c == "r" and j < n and is_ident_start(b[j]):
                start = i
                i = j
                while i < n and is_ident_cont(b[i]):
                    i += 1
                toks.append((b[start:i], IDENT, line))
                continue
        if c == '"' or (c == "b" and i + 1 < n and b[i + 1] == '"'):
            start = i
            i += 2 if c == "b" else 1
            while i < n:
                if b[i] == "\\":
                    i += 2
                    continue
                if b[i] == "\n":
                    line += 1
                if b[i] == '"':
                    i += 1
                    break
                i += 1
            toks.append((b[start:min(i, n)], LITERAL, line))
            continue
        if c == "'":
            if i + 1 < n and is_ident_start(b[i + 1]):
                j = i + 1
                while j < n and is_ident_cont(b[j]):
                    j += 1
                if j >= n or b[j] != "'":
                    toks.append((b[i:j], LIFETIME, line))
                    i = j
                    continue
            start = i
            i += 1
            if i < n and b[i] == "\\":
                i += 2
                while i < n and b[i] != "'":
                    i += 1
            else:
                while i < n and b[i] != "'":
                    i += 1
            i = min(i + 1, n)
            toks.append((b[start:i], LITERAL, line))
            continue
        if is_ident_start(c):
            start = i
            while i < n and is_ident_cont(b[i]):
                i += 1
            toks.append((b[start:i], IDENT, line))
            continue
        if c.isdigit() and c.isascii():
            start = i
            while i < n and is_ident_cont(b[i]):
                i += 1
            if i + 1 < n and b[i] == "." and b[i + 1].isdigit() and b[i + 1].isascii():
                i += 1
                while i < n and is_ident_cont(b[i]):
                    i += 1
            toks.append((b[start:i], LITERAL, line))
            continue
        toks.append((c, PUNCT, line))
        i += 1
    return toks, allows


def allowed(allows, analyzer, line):
    return any(a == analyzer and (ln == line or ln + 1 == line) for ln, a in allows)


class Func:
    def __init__(self, name, params, body):
        self.name = name
        self.params = params
        self.body = body


def match_brace(toks, open_i):
    depth = 0
    i = open_i
    while i < len(toks):
        t = toks[i][0]
        if t == "{":
            depth += 1
        elif t == "}":
            depth -= 1
            if depth == 0:
                return i + 1
        i += 1
    return len(toks)


def push_param(toks, lo, hi, params):
    if lo >= hi:
        return
    i = lo
    while i < hi and (toks[i][0] in ("&", "mut") or toks[i][1] == LIFETIME):
        i += 1
    if i >= hi or toks[i][0] == "self":
        return
    if toks[i][1] == IDENT:
        params.append(toks[i][0])
    else:
        params.append("")  # pattern param: keep index alignment


def parse_params(toks, open_i, params):
    depth = 0
    angle = 0
    i = open_i
    start = open_i + 1
    while i < len(toks):
        t = toks[i][0]
        if t in ("(", "["):
            depth += 1
        elif t in (")", "]"):
            depth -= 1
            if depth == 0:
                push_param(toks, start, i, params)
                return i + 1
        elif t == "<" and depth == 1:
            angle += 1
        elif t == ">" and depth == 1:
            angle -= 1
        elif t == "," and depth == 1 and angle == 0:
            push_param(toks, start, i, params)
            start = i + 1
        i += 1
    return i


def functions(toks):
    out = []
    i = 0
    while i < len(toks):
        if toks[i][1] == IDENT and toks[i][0] == "mod":
            opens = [k for k in range(i, len(toks)) if toks[k][0] in ("{", ";")]
            if opens and toks[opens[0]][0] == "{" and toks[i + 1][0] == "tests":
                i = match_brace(toks, opens[0])
                continue
        if toks[i][1] == IDENT and toks[i][0] == "fn" and i + 1 < len(toks):
            name = toks[i + 1][0]
            j = i + 2
            while j < len(toks) and toks[j][0] not in ("(", "{"):
                j += 1
            params = []
            if j < len(toks) and toks[j][0] == "(":
                j = parse_params(toks, j, params)
            paren = 0
            while j < len(toks):
                t = toks[j][0]
                if t == "(":
                    paren += 1
                elif t == ")":
                    paren -= 1
                elif t == "{" and paren == 0:
                    break
                elif t == ";" and paren == 0:
                    break
                j += 1
            if j < len(toks) and toks[j][0] == "{":
                end = match_brace(toks, j)
                out.append(Func(name, params, (j, end)))
                i = end
                continue
            i = j
            continue
        i += 1
    return out


def split_args(toks, open_i):
    depth = 0
    i = open_i
    args = []
    start = open_i + 1
    while i < len(toks):
        t = toks[i][0]
        if t in ("(", "[", "{"):
            depth += 1
        elif t in (")", "]", "}"):
            depth -= 1
            if depth == 0:
                if start < i:
                    args.append((start, i))
                return args, i + 1
        elif t == "," and depth == 1:
            args.append((start, i))
            start = i + 1
        i += 1
    return args, i


def rel(path):
    if "src/" in path:
        return path.rsplit("src/", 1)[1]
    return path


def tag_tables(files):
    phases = {}
    ops = {}
    for path, (toks, _) in files:
        if not path.endswith("network/tags.rs"):
            continue
        i = 0
        while i + 5 < len(toks):
            if (
                toks[i][0] == "const"
                and toks[i + 1][1] == IDENT
                and toks[i + 2][0] == ":"
                and toks[i + 3][0] == "u8"
                and toks[i + 4][0] == "="
                and toks[i + 5][1] == LITERAL
            ):
                name = toks[i + 1][0]
                lit = toks[i + 5][0].replace("_", "")
                try:
                    val = int(lit, 16) if lit.startswith("0x") else int(lit)
                except ValueError:
                    val = None
                if val is not None and 0 <= val <= 255:
                    if name.startswith("PHASE_"):
                        phases[name] = val
                    elif name.startswith("OP_"):
                        ops[name] = val
                i += 6
                continue
            i += 1
    return phases, ops


ROLE_ROOTS = [
    ("cluster/live.rs", "lead_loop", "leader"),
    ("cluster/live.rs", "finish_trace", "leader"),
    ("cluster/live.rs", "follow_decentralized", "follower"),
    ("cluster/live.rs", "follow_central_worker", "worker"),
]


def role_maps(files, funcs):
    out = []
    for fi, (path, (toks, _)) in enumerate(files):
        file = rel(path)
        names = {f.name for f in funcs[fi]}
        edges = {}
        for f in funcs[fi]:
            callees = edges.setdefault(f.name, set())
            lo, hi = f.body
            for i in range(lo, max(lo, hi - 1)):
                if (
                    toks[i][1] == IDENT
                    and toks[i + 1][0] == "("
                    and toks[i][0] in names
                    and toks[i][0] != f.name
                ):
                    callees.add(toks[i][0])
        labels = {}
        if file.endswith("cli/commands/net_bench.rs"):
            for f in funcs[fi]:
                labels.setdefault(f.name, set()).add("bench")
        for root_file, root_fn, label in ROLE_ROOTS:
            if not file.endswith(root_file):
                continue
            queue = [root_fn]
            seen = set()
            while queue:
                f = queue.pop()
                if f in seen:
                    continue
                seen.add(f)
                labels.setdefault(f, set()).add(label)
                queue.extend(edges.get(f, ()))
        out.append(labels)
    return out


class Ctx:
    def __init__(self, files, funcs, phases):
        self.files = files
        self.funcs = funcs
        self.phases = phases

    def resolve(self, fi, func, lo, hi, depth):
        if depth == 0 or lo >= hi:
            return ("unknown", None)
        toks = self.files[fi][1][0]
        for t in toks[lo:hi]:
            if t[1] == IDENT and t[0] in self.phases:
                return ("phase", t[0])
        s = lo
        while s < hi and toks[s][0] == "&":
            s += 1
        if hi - s == 1 and toks[s][1] == IDENT:
            name = toks[s][0]
            if name in func.params:
                return ("param", func.params.index(name))
            r = self.resolve_let(fi, func, name, depth)
            if r is not None:
                return r
        for i in range(lo, max(lo, hi - 1)):
            if (
                toks[i][1] == IDENT
                and toks[i + 1][0] == "("
                and toks[i][0] not in ("tag", "req_tag")
            ):
                p = self.phase_in_fn_body(toks[i][0])
                if p is not None:
                    return ("phase", p)
        if hi - lo >= 2 and toks[hi - 1][1] == IDENT and toks[hi - 2][0] == ".":
            p = self.resolve_field(toks[hi - 1][0], depth)
            if p is not None:
                return ("phase", p)
        return ("unknown", None)

    def resolve_let(self, fi, func, name, depth):
        toks = self.files[fi][1][0]
        lo, hi = func.body
        i = lo
        while i + 2 < hi:
            if toks[i][0] == "let" and toks[i][1] == IDENT:
                j = i + 1
                if toks[j][0] == "mut":
                    j += 1
                if j < hi and toks[j][1] == IDENT and toks[j][0] == name:
                    k = j + 1
                    while k < hi and toks[k][0] not in ("=", ";"):
                        k += 1
                    if k < hi and toks[k][0] == "=":
                        d = 0
                        e = k + 1
                        while e < hi:
                            t = toks[e][0]
                            if t in ("(", "[", "{"):
                                d += 1
                            elif t in (")", "]", "}"):
                                d -= 1
                            elif t == ";" and d == 0:
                                break
                            e += 1
                        return self.resolve(fi, func, k + 1, e, depth - 1)
            i += 1
        return None

    def phase_in_fn_body(self, name):
        for fi, funcs in enumerate(self.funcs):
            for f in funcs:
                if f.name != name:
                    continue
                toks = self.files[fi][1][0]
                for t in toks[f.body[0]:f.body[1]]:
                    if t[1] == IDENT and t[0] in self.phases:
                        return t[0]
        return None

    def resolve_field(self, field, depth):
        for fi, funcs in enumerate(self.funcs):
            toks = self.files[fi][1][0]
            for f in funcs:
                lo, hi = f.body
                i = lo
                while i + 2 < hi:
                    if (
                        toks[i][1] == IDENT
                        and toks[i][0] == field
                        and toks[i + 1][0] == ":"
                        and toks[i + 2][0] != ":"
                    ):
                        d = 0
                        e = i + 2
                        while e < hi:
                            t = toks[e][0]
                            if t in ("(", "[", "{"):
                                d += 1
                            elif t in (")", "]", "}"):
                                if d == 0:
                                    break
                                d -= 1
                            elif t in (",", ";") and d == 0:
                                break
                            e += 1
                        kind, p = self.resolve(fi, f, i + 2, e, depth - 1)
                        if kind == "phase":
                            return p
                        i = e
                        continue
                    i += 1
        return None


def analyze(files):
    """files: list of (path, (toks, allows)). Returns (graph, findings)."""
    findings = []
    phases, ops = tag_tables(files)
    if not phases:
        findings.append(("network/tags.rs", 0, "protocol: no PHASE_* constants found"))
        return None, findings
    phase_list = sorted(phases.items(), key=lambda kv: (kv[1], kv[0]))
    op_list = sorted(ops.items(), key=lambda kv: (kv[1], kv[0]))
    funcs = [functions(t) for _, (t, _) in files]
    ctx = Ctx(files, funcs, phases)
    roles = role_maps(files, funcs)
    graph = {
        "phases": phase_list,
        "ops": op_list,
        "sends": {},
        "recvs": {},
        "emits": {},
        "dispatches": {},
    }

    def site(fi, func):
        labels = roles[fi].get(func.name)
        r = "|".join(sorted(labels)) if labels else "other"
        return (rel(files[fi][0]), func.name, r)

    # Pass 1: primitive fabric calls.
    raw = []
    for fi, (_, (toks, _)) in enumerate(files):
        for func in funcs[fi]:
            lo, hi = func.body
            i = lo
            while i + 2 < hi:
                if toks[i][0] == "." and toks[i + 1][1] == IDENT and toks[i + 2][0] == "(":
                    args, after = split_args(toks, i + 2)
                    hit = {
                        ("send", 3): ("send", 1),
                        ("broadcast", 2): ("send", 0),
                        ("recv_tag", 2): ("recv", 0),
                        ("gather", 2): ("recv", 0),
                    }.get((toks[i + 1][0], len(args)))
                    if hit is not None:
                        d, argi = hit
                        raw.append((fi, func, d, args[argi], toks[i + 1][2]))
                        i = after
                        continue
                i += 1

    wrappers = {}
    for fi, func, d, arg, line in raw:
        kind, v = ctx.resolve(fi, func, arg[0], arg[1], 4)
        if kind == "phase":
            m = graph["sends"] if d == "send" else graph["recvs"]
            m.setdefault(v, set()).add(site(fi, func))
        elif kind == "param":
            wrappers[(func.name, v)] = d
        else:
            _, allows = files[fi][1]
            if not allowed(allows, "unresolved_tag", line):
                findings.append((
                    rel(files[fi][0]),
                    line,
                    "protocol: %s: cannot resolve the tag of this fabric call to a "
                    "PHASE_* constant" % func.name,
                ))

    # Pass 2: wrapper call sites, transitively.
    for _ in range(8):
        new_wrappers = {}
        for fi, (_, (toks, _)) in enumerate(files):
            for f in funcs[fi]:
                lo, hi = f.body
                i = lo
                while i + 1 < hi:
                    is_def = i > 0 and toks[i - 1][0] == "fn"
                    if toks[i][1] == IDENT and toks[i + 1][0] == "(" and not is_def:
                        entries = sorted(
                            (idx, d)
                            for (n, idx), d in wrappers.items()
                            if n == toks[i][0]
                        )
                        if entries:
                            args, after = split_args(toks, i + 1)
                            for idx, d in entries:
                                if idx >= len(args):
                                    continue
                                a = args[idx]
                                kind, v = ctx.resolve(fi, f, a[0], a[1], 4)
                                if kind == "phase":
                                    m = graph["sends"] if d == "send" else graph["recvs"]
                                    m.setdefault(v, set()).add(site(fi, f))
                                elif kind == "param":
                                    new_wrappers[(f.name, v)] = d
                            i = after
                            continue
                    i += 1
        before = len(wrappers)
        wrappers.update(new_wrappers)
        if len(wrappers) == before:
            break

    # Pass 3: opcode inventory + unbounded receives.
    for fi, (path, (toks, allows)) in enumerate(files):
        if path.endswith("network/tags.rs"):
            continue
        for f in funcs[fi]:
            lo, hi = f.body
            i = lo
            while i < hi:
                t = toks[i]
                if t[1] == IDENT and t[0] in ops:
                    nxt = toks[i + 1][0] if i + 1 < len(toks) else ""
                    nxt2 = toks[i + 2][0] if i + 2 < len(toks) else ""
                    arm = nxt == "=" and nxt2 == ">"
                    eq_r = nxt == "=" and nxt2 == "="
                    eq_l = (
                        i >= 2
                        and toks[i - 1][0] == "="
                        and toks[i - 2][0] == "="
                        and (i < 3 or toks[i - 3][0] != "=")
                    )
                    key = "dispatches" if (arm or eq_r or eq_l) else "emits"
                    graph[key].setdefault(t[0], set()).add(site(fi, f))
                if (
                    t[0] == "."
                    and i + 3 < len(toks)
                    and toks[i + 1][0] == "recv"
                    and toks[i + 2][0] == "("
                    and toks[i + 3][0] == ")"
                ):
                    line = toks[i + 1][2]
                    if not allowed(allows, "unbounded_recv", line):
                        findings.append((
                            rel(path),
                            line,
                            "protocol: %s: unbounded blocking `.recv()`" % f.name,
                        ))
                    i += 4
                    continue
                i += 1

    for name, _ in graph["phases"]:
        s = graph["sends"].get(name, set())
        r = graph["recvs"].get(name, set())
        if s and not r:
            findings.append((
                "network/tags.rs",
                0,
                "protocol: orphan send on %s: sent by [%s] but no receive site exists"
                % (name, ", ".join(fmt_site(x) for x in sorted(s))),
            ))
        if r and not s:
            findings.append((
                "network/tags.rs",
                0,
                "protocol: dead channel %s: received by [%s] but nothing sends it"
                % (name, ", ".join(fmt_site(x) for x in sorted(r))),
            ))
    for name, _ in graph["ops"]:
        e = graph["emits"].get(name, set())
        d = graph["dispatches"].get(name, set())
        if d and not e:
            findings.append((
                "network/tags.rs", 0,
                "protocol: opcode %s is dispatched but no sender emits it" % name,
            ))
        if e and not d:
            findings.append((
                "network/tags.rs", 0,
                "protocol: opcode %s is emitted but no handler dispatches it" % name,
            ))

    return graph, findings


def fmt_site(s):
    file, func, roles = s
    return "%s:%s@%s" % (roles, func, file)


def fmt_sites(st):
    return "[%s]" % ", ".join(fmt_site(x) for x in sorted(st or ()))


def render_map(g):
    out = [
        "# apple-moe protocol map: the fabric communication graph extracted from\n"
        "# rust/src (send/broadcast vs recv_tag/gather sites per PHASE_*, opcode\n"
        "# emit vs dispatch sites per OP_*). Regenerate after an intentional\n"
        "# protocol-flow change:\n"
        "#   cargo xtask protocol --bless    (or: python3 tools/protocol_map.py --bless)\n"
        "# Do not hand-edit.\n\n[edges]\n"
    ]
    for name, val in g["phases"]:
        sends = fmt_sites(g["sends"].get(name))
        recvs = fmt_sites(g["recvs"].get(name))
        if sends == "[]" and recvs == "[]":
            continue
        out.append("%s=%d sends=%s recvs=%s\n" % (name, val, sends, recvs))
    out.append("\n[ops]\n")
    for name, val in g["ops"]:
        emit = fmt_sites(g["emits"].get(name))
        dispatch = fmt_sites(g["dispatches"].get(name))
        if emit == "[]" and dispatch == "[]":
            continue
        out.append("%s=%d emit=%s dispatch=%s\n" % (name, val, emit, dispatch))
    out.append("\n[mermaid]\nsequenceDiagram\n")
    arrows = []
    seen = set()
    for name, val in g["phases"]:
        senders = set()
        for s in g["sends"].get(name, ()):
            senders.update(s[2].split("|"))
        recvers = set()
        for s in g["recvs"].get(name, ()):
            recvers.update(s[2].split("|"))
        pairs = [(a, b) for a in sorted(senders) for b in sorted(recvers) if a != b]
        if not pairs:
            pairs = [(a, a) for a in sorted(senders) if a in recvers]
        for a, b in pairs:
            if (val, a, b) not in seen:
                seen.add((val, a, b))
                arrows.append((val, a, b, name))
    arrows.sort()
    used = {x for _, a, b, _ in arrows for x in (a, b)}
    for p in ("leader", "follower", "worker", "bench", "other"):
        if p in used:
            out.append("    participant %s\n" % p)
    for _, a, b, phase in arrows:
        out.append("    %s->>%s: %s\n" % (a, b, phase))
    return "".join(out)


def collect_sources(root):
    out = []

    def walk(d):
        for entry in sorted(os.listdir(d)):
            p = os.path.join(d, entry)
            if os.path.isdir(p):
                walk(p)
            elif p.endswith(".rs"):
                with open(p, encoding="utf-8") as f:
                    out.append((p.replace("\\", "/"), f.read()))

    walk(root)
    return out


def main(argv):
    bless = "--bless" in argv
    files = [(p, lex(src)) for p, src in collect_sources(os.path.join(RUST, "src"))]
    graph, findings = analyze(files)
    for file, line, msg in findings:
        print("%s:%d: %s" % (file, line, msg))
    if findings:
        print("protocol: FAILED (%d finding(s))" % len(findings))
        return 1
    text = render_map(graph)
    if bless:
        with open(MAP, "w", encoding="utf-8") as f:
            f.write(text)
        print("blessed %s" % MAP)
        return 0
    try:
        with open(MAP, encoding="utf-8") as f:
            current = f.read()
    except FileNotFoundError:
        current = ""
    if current == text:
        print("protocol.map is up to date")
        return 0
    print("protocol.map is stale — run `cargo xtask protocol --bless` (or this")
    print("script with --bless) after an intentional protocol-flow change:")
    sys.stdout.write(text)
    return 1


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
