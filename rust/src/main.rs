//! `apple-moe` CLI — see `apple-moe help` or `rust/src/cli/mod.rs`.

// The one sanctioned `exit` (the workspace denies `clippy::exit`
// elsewhere): the process boundary, after the error has been printed.
#[allow(clippy::exit)]
fn main() {
    apple_moe::util::logging::init();
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = apple_moe::cli::run(argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
