//! Integration: CLI subcommands end-to-end through the library entry
//! point (`cli::run`), checking they execute and print the expected
//! table shapes. The live subcommands are covered by
//! `integration_cluster.rs`; here we exercise the analysis commands.

// Test code: a panic is the failure report (see clippy.toml).
#![allow(clippy::unwrap_used)]

use apple_moe::cli;

fn run(cmd: &str) -> anyhow::Result<()> {
    cli::run(cmd.split_whitespace().map(String::from).collect())
}

#[test]
fn simulate_all_strategies() {
    for s in ["naive", "p-lb", "p-lr-d"] {
        run(&format!("simulate --strategy {s} --nodes 2 --gen-tokens 16 --prompt-tokens 8"))
            .unwrap_or_else(|e| panic!("simulate {s}: {e:#}"));
    }
}

#[test]
fn simulate_rejects_bad_input() {
    assert!(run("simulate --strategy bogus").is_err());
    assert!(run("simulate --nodes 0").is_err());
    assert!(run("simulate --nodes two").is_err());
    assert!(run("simulate --bogus-flag 1").is_err());
}

#[test]
fn perf_model_and_cost() {
    run("perf-model --max-nodes 4").unwrap();
    run("cost").unwrap();
}

#[test]
fn cluster_info_both_models() {
    run("cluster-info --nodes 2").unwrap();
    run("cluster-info --nodes 4 --model dbrx-nano").unwrap();
    assert!(run("cluster-info --model gpt5").is_err());
}

#[test]
fn packing_bench_small() {
    run("packing-bench --samples 1").unwrap();
}

#[test]
fn multiuser_runs_and_validates() {
    run("multiuser --requests 3 --rate 0.1 --gen-tokens 16 --prompt-tokens 8").unwrap();
    run("multiuser --requests 3 --rate 0.1 --policy fcfs --gen-tokens 16 --prompt-tokens 8")
        .unwrap();
    assert!(run("multiuser --rate 0").is_err());
    assert!(run("multiuser --policy sjf").is_err());
}

#[test]
fn net_bench_runs_both_backends() {
    // Small but real: exercises the in-process AND loopback-TCP
    // transports end-to-end (no artifacts needed).
    run("net-bench --iters 4 --warmup 1 --payload 2048 --stream-msgs 8").unwrap();
}

#[test]
fn net_bench_rejects_bad_input() {
    assert!(run("net-bench --backend carrier-pigeon").is_err());
    assert!(run("net-bench --iters 0").is_err());
}

#[test]
fn serve_and_generate_validate_args() {
    // All of these fail during flag parsing/validation, before any
    // cluster (or artifacts) are touched.
    assert!(run("serve --concurrency 0").is_err());
    assert!(run("serve --transport carrier-pigeon").is_err());
    assert!(run("serve --policy sjf").is_err());
    assert!(run("serve --requests 0").is_err());
    assert!(run("serve --sampler bogus").is_err());
    assert!(run("serve --stop 1,x,3").is_err());
    assert!(run("generate --sampler bogus").is_err());
    assert!(run("generate --stop ,,a").is_err());
}

#[test]
fn node_and_launch_validate_args() {
    // `node` needs an id and a hosts file before it touches the network.
    assert!(run("node").is_err());
    assert!(run("node --id 0").is_err());
    assert!(run("node --id 0 --cluster /nonexistent/hosts.toml").is_err());
    // Only the scheduler (node 0) can own the client port.
    assert!(run("node --id 1 --cluster /nonexistent/hosts.toml --client-port 7533").is_err());
    assert!(run("node --id 0 --cluster /nonexistent/hosts.toml --client-port notaport").is_err());
    // `launch` cross-checks --nodes against the hosts file.
    assert!(run("launch --nodes 0").is_err());
    assert!(run("launch --cluster /nonexistent/hosts.toml").is_err());
}

#[test]
fn client_validates_args_before_dialing() {
    // All of these fail during flag parsing, before any socket is
    // opened (so no daemon is needed).
    assert!(run("client").is_err()); // --connect required
    assert!(run("client --connect 127.0.0.1:1 --prompt 1,2 --requests 2").is_err());
    assert!(run("client --connect 127.0.0.1:1 --prompt x,y").is_err());
    assert!(run("client --connect 127.0.0.1:1 --prompt ,").is_err());
    assert!(run("client --connect 127.0.0.1:1 --sampler bogus").is_err());
}

#[test]
fn help_and_unknown() {
    run("help").unwrap();
    assert!(run("frobnicate").is_err());
}
