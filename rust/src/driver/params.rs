//! Calibration constants for the simulated Metal driver.
//!
//! Anchors from the paper's Fig. 4 benchmark (Algorithm 1 + 2, 40 layers ×
//! 3 matrices of 8192×8192 f32 ≈ 268 MB each, 32 GB prestacked):
//!
//! 1. Prestacking "requires a longer time (400 ms) initially for the
//!    driver to load the larger data" ⇒ wiring 32 GB ≈ 400 ms ⇒ effective
//!    wiring bandwidth ≈ 80 GB/s (plus a fixed per-array driver call).
//! 2. The unstacked curve departs at `T_wait ≈ 8 ms`. The inter-touch gap
//!    of a given layer's matrix in Algorithm 2 is one full pass,
//!    ≈ `40 × (compute + T_wait)` ≈ 380 ms at 8 ms and ≈ 220 ms at 4 ms,
//!    so the inactivity window for a 268 MB array sits in (220, 380) ms.
//! 3. The prestacked curve departs at `T_wait ≈ 512 ms` and the stack is
//!    touched every layer, so the window for a 32 GB array ≈ 512 ms.
//!
//! We interpolate the window log-linearly in array size between those two
//! anchors and clamp to `[min_window, max_window]`.

use crate::simclock::{Nanos, NS_PER_MS};

#[derive(Debug, Clone, PartialEq)]
pub struct DriverParams {
    /// Effective first-wire bandwidth, bytes/sec (anchor 1: ≈80 GB/s —
    /// includes faulting the pages in from the file mapping).
    pub wire_bw: f64,
    /// Re-wire bandwidth, bytes/sec: re-pinning pages that are still
    /// resident skips the page-in, so it runs at closer to memcpy speed
    /// (≈200 GB/s; calibrated against Table 3's naive MoE column).
    pub rewire_bw: f64,
    /// Fixed per-array driver-call overhead, ns.
    pub fixed_ns: Nanos,
    /// Inactivity window anchors: (bytes, window_ns) pairs for the
    /// log-linear interpolation.
    pub window_lo_bytes: u64,
    pub window_lo_ns: Nanos,
    pub window_hi_bytes: u64,
    pub window_hi_ns: Nanos,
    /// Clamp bounds on the interpolated window.
    pub min_window_ns: Nanos,
    pub max_window_ns: Nanos,
}

impl Default for DriverParams {
    fn default() -> Self {
        const MB: u64 = 1024 * 1024;
        const GB: u64 = 1024 * MB;
        DriverParams {
            wire_bw: 80e9,
            rewire_bw: 200e9,
            fixed_ns: 300_000,
            window_lo_bytes: 268 * MB,
            window_lo_ns: 300 * NS_PER_MS,
            // Slightly above the last stable sweep point: the paper's
            // prestacked curve departs only once T_wait *exceeds* 512 ms,
            // so the 32 GB array's window must cover 512 ms of sleep plus
            // the layer's compute time.
            window_hi_bytes: 32 * GB,
            window_hi_ns: 560 * NS_PER_MS,
            min_window_ns: 50 * NS_PER_MS,
            max_window_ns: 600 * NS_PER_MS,
        }
    }
}

impl DriverParams {
    /// Driver time to wire `bytes` for the first time.
    pub fn wire_cost(&self, bytes: u64) -> Nanos {
        self.fixed_ns + (bytes as f64 / self.wire_bw * 1e9) as Nanos
    }

    /// Driver time to re-wire `bytes` that were unwired by inactivity.
    pub fn rewire_cost(&self, bytes: u64) -> Nanos {
        self.fixed_ns + (bytes as f64 / self.rewire_bw * 1e9) as Nanos
    }

    /// Inactivity window after which an array of `bytes` is unwired.
    pub fn unwire_after(&self, bytes: u64) -> Nanos {
        let lo_b = (self.window_lo_bytes.max(1)) as f64;
        let hi_b = (self.window_hi_bytes.max(2)) as f64;
        let lo_w = self.window_lo_ns as f64;
        let hi_w = self.window_hi_ns as f64;
        let x = (bytes.max(1)) as f64;
        let t = ((x.log2() - lo_b.log2()) / (hi_b.log2() - lo_b.log2())).clamp(-2.0, 2.0);
        let w = lo_w + (hi_w - lo_w) * t;
        (w as Nanos).clamp(self.min_window_ns, self.max_window_ns)
    }

    /// A driver with wiring disabled (infinite window, zero cost) — the
    /// "ideal driver" ablation.
    pub fn ideal() -> DriverParams {
        DriverParams {
            wire_bw: f64::INFINITY,
            rewire_bw: f64::INFINITY,
            fixed_ns: 0,
            min_window_ns: Nanos::MAX / 4,
            max_window_ns: Nanos::MAX / 2,
            ..Default::default()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_hit_anchor_values() {
        let p = DriverParams::default();
        assert_eq!(p.unwire_after(p.window_lo_bytes), p.window_lo_ns);
        assert_eq!(p.unwire_after(p.window_hi_bytes), p.window_hi_ns);
    }

    #[test]
    fn window_is_monotone_in_bytes() {
        let p = DriverParams::default();
        let mut prev = 0;
        for pow in 18..40 {
            let w = p.unwire_after(1u64 << pow);
            assert!(w >= prev, "window must not shrink with size");
            prev = w;
        }
    }

    #[test]
    fn window_clamped() {
        let p = DriverParams::default();
        assert_eq!(p.unwire_after(1), p.min_window_ns);
        assert_eq!(p.unwire_after(u64::MAX / 2), p.max_window_ns);
    }

    #[test]
    fn ideal_driver_never_unwires_or_charges() {
        let p = DriverParams::ideal();
        assert_eq!(p.wire_cost(32 << 30), 0);
        assert!(p.unwire_after(1) > 1_000_000_000_000); // >1000 s
    }

    #[test]
    fn wire_cost_32gb_near_400ms() {
        let p = DriverParams::default();
        let ms = p.wire_cost(32 << 30) / NS_PER_MS;
        assert!((390..=440).contains(&ms), "{ms} ms");
    }
}
