//! Clocks for the two execution modes of the cluster (DESIGN.md §5).
//!
//! Paper-scale phenomena (driver wiring, 10 GbE latency) are milliseconds
//! while the nano model's real compute is microseconds, so benches that
//! regenerate the paper's tables run on a *virtual* clock advanced by the
//! cost models, and the real end-to-end path uses the wall clock. All
//! coordinator logic is written against the `Clock` trait so both modes
//! share routing/balancing/protocol code.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

/// Nanoseconds since clock epoch.
pub type Nanos = u64;

pub const NS_PER_US: u64 = 1_000;
pub const NS_PER_MS: u64 = 1_000_000;
pub const NS_PER_SEC: u64 = 1_000_000_000;

/// Convert seconds (f64) to nanos, saturating.
pub fn secs_to_ns(s: f64) -> Nanos {
    if s <= 0.0 {
        0
    } else {
        (s * 1e9).round() as u64
    }
}

/// Convert nanos to seconds.
pub fn ns_to_secs(ns: Nanos) -> f64 {
    ns as f64 / 1e9
}

/// A monotonic clock the simulation can either advance manually (virtual
/// mode) or read from the OS (real mode).
pub trait Clock: Send + Sync {
    /// Current time in nanoseconds since the clock's epoch.
    fn now(&self) -> Nanos;
    /// Advance the clock by `ns`. Virtual clocks jump; the real clock
    /// sleeps (used to inject simulated link latency into live runs).
    fn advance(&self, ns: Nanos);
    /// True if time is simulated (benches) rather than wall time.
    fn is_virtual(&self) -> bool;
}

/// Virtual clock: an atomic counter. `advance` is a simple add, `now` a
/// load. Deterministic and free, which is what the DES needs.
#[derive(Debug, Default)]
pub struct VirtualClock {
    ns: AtomicU64,
}

impl VirtualClock {
    pub fn new() -> Arc<Self> {
        Arc::new(VirtualClock { ns: AtomicU64::new(0) })
    }

    /// Set the clock to an absolute time (DES event dispatch). Only moves
    /// forward; going backwards is a simulation bug.
    pub fn set(&self, t: Nanos) {
        let prev = self.ns.swap(t, Ordering::SeqCst);
        debug_assert!(t >= prev, "virtual clock moved backwards: {prev} -> {t}");
    }
}

impl Clock for VirtualClock {
    fn now(&self) -> Nanos {
        self.ns.load(Ordering::SeqCst)
    }

    fn advance(&self, ns: Nanos) {
        self.ns.fetch_add(ns, Ordering::SeqCst);
    }

    fn is_virtual(&self) -> bool {
        true
    }
}

/// Wall clock anchored at construction.
#[derive(Debug)]
pub struct RealClock {
    epoch: Instant,
}

impl RealClock {
    pub fn new() -> Arc<Self> {
        Arc::new(RealClock { epoch: Instant::now() })
    }
}

impl Clock for RealClock {
    fn now(&self) -> Nanos {
        self.epoch.elapsed().as_nanos() as u64
    }

    fn advance(&self, ns: Nanos) {
        // Injecting virtual delay into a live run = actually waiting.
        if ns > 0 {
            std::thread::sleep(std::time::Duration::from_nanos(ns));
        }
    }

    fn is_virtual(&self) -> bool {
        false
    }
}

/// A stopwatch over any `Clock`.
pub struct Stopwatch<'a> {
    clock: &'a dyn Clock,
    start: Nanos,
}

impl<'a> Stopwatch<'a> {
    pub fn start(clock: &'a dyn Clock) -> Self {
        Stopwatch { clock, start: clock.now() }
    }

    pub fn elapsed(&self) -> Nanos {
        self.clock.now().saturating_sub(self.start)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn virtual_clock_advances() {
        let c = VirtualClock::new();
        assert_eq!(c.now(), 0);
        c.advance(5 * NS_PER_MS);
        assert_eq!(c.now(), 5 * NS_PER_MS);
        c.set(10 * NS_PER_MS);
        assert_eq!(c.now(), 10 * NS_PER_MS);
        assert!(c.is_virtual());
    }

    #[test]
    fn real_clock_progresses() {
        let c = RealClock::new();
        let t0 = c.now();
        std::thread::sleep(std::time::Duration::from_millis(2));
        assert!(c.now() > t0);
        assert!(!c.is_virtual());
    }

    #[test]
    fn stopwatch_over_virtual() {
        let c = VirtualClock::new();
        let sw = Stopwatch::start(&*c);
        c.advance(123);
        assert_eq!(sw.elapsed(), 123);
    }

    #[test]
    fn conversions_roundtrip() {
        assert_eq!(secs_to_ns(0.001), NS_PER_MS);
        assert_eq!(secs_to_ns(1.0), NS_PER_SEC);
        assert!((ns_to_secs(NS_PER_SEC) - 1.0).abs() < 1e-12);
        assert_eq!(secs_to_ns(-1.0), 0);
    }
}
