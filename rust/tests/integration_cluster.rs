//! Integration: the live threaded cluster (decentralized P-L_R-D wire
//! protocol AND centralized Figs. 2–3 protocol) generates exactly the
//! same tokens as the dense single-node engine — the correctness claim
//! behind Table 3's comparisons.

use std::path::{Path, PathBuf};

use apple_moe::cluster::live::{LiveCluster, LiveConfig};
use apple_moe::config::{Balancing, Topology};
use apple_moe::engine::{DenseEngine, Request, Sampler};

fn artifacts_dir() -> Option<PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.txt").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: run `make artifacts` first");
        None
    }
}

fn dense_tokens(dir: &Path, req: &Request) -> Vec<u32> {
    let mut engine = DenseEngine::load(dir, Sampler::Greedy, 1).unwrap();
    engine.serve(req).unwrap().generated
}

#[test]
fn decentralized_two_nodes_matches_dense() {
    let Some(dir) = artifacts_dir() else { return };
    let req = Request::new(1, vec![3, 141, 59, 26], 12);
    let want = dense_tokens(&dir, &req);
    assert_eq!(want.len(), 12);

    let cfg = LiveConfig::new(dir.clone(), 2);
    let cluster = LiveCluster::start(cfg).unwrap();
    let res = cluster.serve(req).unwrap();
    cluster.shutdown();
    assert_eq!(res.generated, want, "distributed generation diverged");
    assert_eq!(res.metrics.decode.tokens, 12);
    // The all-reduce path must actually have been exercised.
    assert!(res.metrics.decode.breakdown_secs().1 > 0.0, "no comm time?");
}

#[test]
fn centralized_two_nodes_matches_dense() {
    let Some(dir) = artifacts_dir() else { return };
    let req = Request::new(2, vec![10, 20, 30], 8);
    let want = dense_tokens(&dir, &req);

    let mut cfg = LiveConfig::new(dir.clone(), 2);
    cfg.topology = Topology::Centralized;
    cfg.balancing = Balancing::SelectedOnly;
    let cluster = LiveCluster::start(cfg).unwrap();
    let res = cluster.serve(req).unwrap();
    cluster.shutdown();
    assert_eq!(res.generated, want, "centralized generation diverged");
}

#[test]
fn busy_full_loading_matches_dense() {
    // P-L_B runs every expert every layer with zeroed padding — numerics
    // must be unchanged (§4.2).
    let Some(dir) = artifacts_dir() else { return };
    let req = Request::new(3, vec![100, 200], 6);
    let want = dense_tokens(&dir, &req);

    let mut cfg = LiveConfig::new(dir.clone(), 2);
    cfg.balancing = Balancing::BusyFull;
    let cluster = LiveCluster::start(cfg).unwrap();
    let res = cluster.serve(req).unwrap();
    cluster.shutdown();
    assert_eq!(res.generated, want, "busy-full generation diverged");
}

#[test]
fn single_node_cluster_works() {
    let Some(dir) = artifacts_dir() else { return };
    let req = Request::new(4, vec![42], 5);
    let want = dense_tokens(&dir, &req);
    let cluster = LiveCluster::start(LiveConfig::new(dir.clone(), 1)).unwrap();
    let res = cluster.serve(req).unwrap();
    cluster.shutdown();
    assert_eq!(res.generated, want);
}

/// Serve `req` on a cluster forced to the given decode path.
fn serve_on_path(
    dir: &Path,
    nodes: usize,
    topology: Topology,
    device_resident: bool,
    req: &Request,
) -> apple_moe::engine::request::RequestResult {
    let mut cfg = LiveConfig::new(dir.to_path_buf(), nodes);
    cfg.topology = topology;
    if topology == Topology::Centralized {
        cfg.balancing = Balancing::SelectedOnly;
    }
    cfg.device_resident = device_resident;
    let cluster = LiveCluster::start(cfg).unwrap();
    let res = cluster.serve(req.clone()).unwrap();
    cluster.shutdown();
    res
}

/// The §Perf acceptance: for both topologies and 1/2 nodes, the
/// device-resident decode loop generates the same tokens as the
/// host-roundtrip reference loop — while performing ZERO per-layer K/V
/// cache host crossings (the per-token transfer counters stay under one
/// cache's size; the reference path moves every cache twice per layer).
#[test]
fn device_resident_cluster_matches_host_path() {
    let Some(dir) = artifacts_dir() else { return };
    let manifest = apple_moe::runtime::Manifest::load(&dir).unwrap();
    if !manifest.device_artifacts {
        eprintln!("skipping: artifacts predate the dev_* set");
        return;
    }
    let req = Request::new(10, vec![3, 141, 59], 8);
    // One full generation of K/V caches (all layers, one direction).
    let caches_bytes = (manifest.n_kv_heads
        * manifest.max_seq
        * manifest.head_dim
        * 4
        * manifest.n_layers) as f64;

    for topology in [Topology::Decentralized, Topology::Centralized] {
        for nodes in [1usize, 2] {
            let host = serve_on_path(&dir, nodes, topology, false, &req);
            let dev = serve_on_path(&dir, nodes, topology, true, &req);
            assert_eq!(
                dev.generated, host.generated,
                "tokens diverge: {topology:?} x {nodes} nodes"
            );
            // Decode-phase transfer accounting: the host path
            // round-trips all caches every token; the device path must
            // stay under a quarter of ONE cache generation per token.
            let host_bpt = host.metrics.decode.transfer_bytes_per_token();
            let dev_bpt = dev.metrics.decode.transfer_bytes_per_token();
            assert!(
                host_bpt > caches_bytes,
                "host path moved {host_bpt} B/token — meter broken? ({topology:?} x {nodes})"
            );
            assert!(
                dev_bpt < caches_bytes / 4.0,
                "device path moved {dev_bpt} B/token ({topology:?} x {nodes})"
            );
            assert!(
                dev_bpt < host_bpt / 10.0,
                "device path should move >=10x fewer bytes: {dev_bpt} vs {host_bpt}"
            );
        }
    }
}

#[test]
fn multiple_requests_reuse_cluster() {
    let Some(dir) = artifacts_dir() else { return };
    let cluster = LiveCluster::start(LiveConfig::new(dir.clone(), 2)).unwrap();
    let r1 = cluster.serve(Request::new(5, vec![1, 2, 3], 4)).unwrap();
    let r2 = cluster.serve(Request::new(6, vec![9, 9], 4)).unwrap();
    cluster.shutdown();
    assert_eq!(r1.generated.len(), 4);
    assert_eq!(r2.generated.len(), 4);
    // Same prompts must reproduce across a fresh cluster (KV state and
    // sampler reset per request).
    let cluster2 = LiveCluster::start(LiveConfig::new(dir, 2)).unwrap();
    let r1b = cluster2.serve(Request::new(7, vec![1, 2, 3], 4)).unwrap();
    cluster2.shutdown();
    assert_eq!(r1.generated, r1b.generated);
}
