//! Configuration: model dimensions, node hardware, network profiles,
//! cluster layout, engine/run parameters. Values default to the paper's
//! Table 1 / Table 2 and can be overridden from a TOML-subset file
//! (`toml.rs`) or CLI flags.

pub mod toml;

use std::fmt;
use std::path::Path;
use std::time::Duration;

use crate::config::toml::{Document, Value};

/// Model architecture dimensions (decoder-only MoE, DBRX-shaped).
#[derive(Debug, Clone, PartialEq)]
pub struct ModelDims {
    pub name: String,
    pub n_layers: usize,
    /// Embedding / residual width (`D_embed`, paper: 6144).
    pub d_embed: usize,
    /// Total QKV projection output width (`D_qkv_hidden`, paper: 8192).
    pub d_qkv_hidden: usize,
    /// Expert FFN hidden width (`D_ffn`, paper: 10752).
    pub d_ffn: usize,
    pub n_experts: usize,
    /// Experts activated per token (DBRX: 4 of 16).
    pub top_k: usize,
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub vocab_size: usize,
    /// Bytes per parameter (2 = bf16, the paper's "precision").
    pub precision_bytes: usize,
}

impl ModelDims {
    /// The paper's target: unquantized DBRX Instruct 132B (Table 1).
    pub fn dbrx_132b() -> ModelDims {
        ModelDims {
            name: "dbrx-132b".into(),
            n_layers: 40,
            d_embed: 6144,
            d_qkv_hidden: 8192,
            d_ffn: 10752,
            n_experts: 16,
            top_k: 4,
            n_heads: 48,
            n_kv_heads: 8,
            vocab_size: 100_352,
            precision_bytes: 2,
        }
    }

    /// Scaled-down DBRX-architecture model that is actually executed via
    /// Pallas → HLO → PJRT CPU in examples and integration tests. Same
    /// expert count / top-k (so routing statistics match) and the same
    /// GQA structure; only widths shrink.
    pub fn dbrx_nano() -> ModelDims {
        ModelDims {
            name: "dbrx-nano".into(),
            n_layers: 4,
            d_embed: 256,
            d_qkv_hidden: 512, // (n_heads + 2*n_kv_heads) * head_dim
            d_ffn: 448,
            n_experts: 16,
            top_k: 4,
            n_heads: 8,
            n_kv_heads: 4,
            vocab_size: 512,
            precision_bytes: 4, // f32 on the CPU PJRT path
        }
    }

    pub fn head_dim(&self) -> usize {
        // d_qkv_hidden = (n_heads + 2 * n_kv_heads) * head_dim
        self.d_qkv_hidden / (self.n_heads + 2 * self.n_kv_heads)
    }

    pub fn by_name(name: &str) -> Option<ModelDims> {
        match name {
            "dbrx-132b" => Some(Self::dbrx_132b()),
            "dbrx-nano" => Some(Self::dbrx_nano()),
            _ => None,
        }
    }
}

/// Per-node hardware (Table 2: Mac Studio, M2 Ultra).
#[derive(Debug, Clone, PartialEq)]
pub struct NodeHardware {
    pub name: String,
    pub mem_bytes: u64,
    /// Unified memory bandwidth, bytes/sec (Table 1: 800e9).
    pub mem_bw: f64,
    /// GPU BF16 FLOPS per node (Table 1: 54e12).
    pub gpu_bf16_flops: f64,
    /// List price per node in USD (Table 5: 6,599).
    pub price_usd: f64,
    /// Memory-bandwidth efficiency actually achieved by expert matmuls
    /// (calibration constant; the paper's measured MoE times imply ≈0.66
    /// of peak — see EXPERIMENTS.md §Calibration).
    pub mem_efficiency: f64,
}

impl NodeHardware {
    pub fn m2_ultra() -> NodeHardware {
        NodeHardware {
            name: "mac-studio-m2-ultra".into(),
            mem_bytes: 192 * 1024 * 1024 * 1024,
            mem_bw: 800e9,
            gpu_bf16_flops: 54e12,
            price_usd: 6_599.0,
            mem_efficiency: 0.66,
        }
    }

    /// The Databricks comparison system (Table 5): one DGX-class node
    /// with 8×H100-80G, list price 289,000 USD, measured 112.5 tok/s.
    pub fn dgx_h100_8x() -> NodeHardware {
        NodeHardware {
            name: "8x-h100-80g".into(),
            mem_bytes: 8 * 80 * 1024 * 1024 * 1024,
            mem_bw: 8.0 * 3.35e12,
            gpu_bf16_flops: 8.0 * 989e12,
            price_usd: 289_000.0,
            mem_efficiency: 0.66,
        }
    }
}

/// Interconnect profile: per-message transport latency + link bandwidth
/// (+ NIC price for the §5.5 cost projections).
#[derive(Debug, Clone, PartialEq)]
pub struct NetworkProfile {
    pub name: String,
    /// Transport software processing latency per message, ns.
    pub latency_ns: u64,
    /// Link bandwidth in bytes/sec.
    pub bandwidth: f64,
    /// Additional NIC cost per node, USD (0 for the built-in 10 GbE).
    pub nic_price_usd: f64,
}

impl NetworkProfile {
    /// Built-in 10 GbE over TCP/IP (Table 1: 1 ms latency, 1.25e9 B/s).
    pub fn tcp_10gbe() -> NetworkProfile {
        NetworkProfile {
            name: "10gbe-tcp".into(),
            latency_ns: 1_000_000,
            bandwidth: 1.25e9,
            nic_price_usd: 0.0,
        }
    }

    /// RoCEv2 25 Gbps NIC (§5.5: 750 ns, 339 USD).
    pub fn rocev2() -> NetworkProfile {
        NetworkProfile {
            name: "rocev2-25g".into(),
            latency_ns: 750,
            bandwidth: 3.125e9,
            nic_price_usd: 339.0,
        }
    }

    /// Infiniband 200 Gbps NIC (§5.5: 600 ns, 1,267 USD).
    pub fn infiniband() -> NetworkProfile {
        NetworkProfile {
            name: "infiniband-200g".into(),
            latency_ns: 600,
            bandwidth: 25e9,
            nic_price_usd: 1_267.0,
        }
    }

    pub fn by_name(name: &str) -> Option<NetworkProfile> {
        match name {
            "10gbe" | "10gbe-tcp" | "tcp" => Some(Self::tcp_10gbe()),
            "rocev2" | "roce" => Some(Self::rocev2()),
            "infiniband" | "ib" => Some(Self::infiniband()),
            _ => None,
        }
    }
}

/// Weight packing strategy (§4.1 / Algorithm 1).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Packing {
    /// Each weight matrix is a separate array (naive MLX loading).
    Unstacked,
    /// All of an expert's layer weights stacked into one array (`P`).
    Prestacked,
}

/// Multi-node compute load-balancing strategy (§4.2).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Balancing {
    /// Only router-selected experts run (naive).
    SelectedOnly,
    /// Busy full loading (`L_B`): every expert runs every layer.
    BusyFull,
    /// Router-aided dynamic loading (`L_R`): pad each node up to the
    /// cluster-wide max selected count using LRU experts.
    RouterAided,
}

/// Communication topology (§4.3).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Topology {
    /// Fork-join through node 1 (Figs. 2–3): 2 communications per layer,
    /// gRPC served from the GPU process.
    Centralized,
    /// Decentralized attention/router replicas + envoy all-reduce
    /// (`D`, Fig. 7): 1 communication per layer.
    Decentralized,
}

/// A named optimization level from the paper's evaluation (Table 3).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Strategy {
    /// `Naive`: unstacked, selected-only, centralized.
    Naive,
    /// `P-L_B`: prestacked + busy full loading, centralized.
    PLb,
    /// `P-L_R-D`: prestacked + router-aided + decentralized.
    PLrD,
}

impl Strategy {
    pub fn packing(self) -> Packing {
        match self {
            Strategy::Naive => Packing::Unstacked,
            _ => Packing::Prestacked,
        }
    }

    pub fn balancing(self) -> Balancing {
        match self {
            Strategy::Naive => Balancing::SelectedOnly,
            Strategy::PLb => Balancing::BusyFull,
            Strategy::PLrD => Balancing::RouterAided,
        }
    }

    pub fn topology(self) -> Topology {
        match self {
            Strategy::PLrD => Topology::Decentralized,
            _ => Topology::Centralized,
        }
    }

    pub fn by_name(name: &str) -> Option<Strategy> {
        match name.to_ascii_lowercase().as_str() {
            "naive" => Some(Strategy::Naive),
            "p-lb" | "plb" | "p-l_b" => Some(Strategy::PLb),
            "p-lr-d" | "plrd" | "p-l_r-d" => Some(Strategy::PLrD),
            _ => None,
        }
    }

    pub fn all() -> [Strategy; 3] {
        [Strategy::Naive, Strategy::PLb, Strategy::PLrD]
    }
}

impl fmt::Display for Strategy {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            Strategy::Naive => "Naive",
            Strategy::PLb => "P-L_B",
            Strategy::PLrD => "P-L_R-D",
        };
        f.write_str(s)
    }
}

/// Full cluster configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterConfig {
    pub n_nodes: usize,
    pub hardware: NodeHardware,
    pub network: NetworkProfile,
    pub strategy: Strategy,
    /// Max experts a node may hold resident (overlapped placement for
    /// 3–4 node clusters, §5.3). 0 = derive from memory budget.
    pub experts_per_node_cap: usize,
}

impl ClusterConfig {
    pub fn new(n_nodes: usize, strategy: Strategy) -> ClusterConfig {
        ClusterConfig {
            n_nodes,
            hardware: NodeHardware::m2_ultra(),
            network: NetworkProfile::tcp_10gbe(),
            strategy,
            experts_per_node_cap: 0,
        }
    }
}

/// Generation / workload parameters (§5.2: 128/128; Table 5: 2000/256).
#[derive(Debug, Clone, PartialEq)]
pub struct EngineConfig {
    pub model: ModelDims,
    pub prompt_tokens: usize,
    pub gen_tokens: usize,
    pub batch_size: usize,
    pub seed: u64,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            model: ModelDims::dbrx_132b(),
            prompt_tokens: 128,
            gen_tokens: 128,
            batch_size: 1,
            seed: 0xD8B2,
        }
    }
}

/// Errors surfaced when loading/validating configuration.
#[derive(Debug, thiserror::Error)]
pub enum ConfigError {
    #[error("io error reading {path}: {source}")]
    Io {
        path: String,
        #[source]
        source: std::io::Error,
    },
    #[error(transparent)]
    Parse(#[from] toml::ParseError),
    #[error("invalid config: {0}")]
    Invalid(String),
}

/// Load a `ClusterConfig` + `EngineConfig` from a TOML file, with every
/// field optional (defaults = paper setup).
pub fn load_from_file(path: &Path) -> Result<(ClusterConfig, EngineConfig), ConfigError> {
    let text = std::fs::read_to_string(path).map_err(|source| ConfigError::Io {
        path: path.display().to_string(),
        source,
    })?;
    load_from_str(&text)
}

pub fn load_from_str(text: &str) -> Result<(ClusterConfig, EngineConfig), ConfigError> {
    let doc = Document::parse(text)?;

    let strategy_name = doc.str_or("cluster.strategy", "p-lr-d").to_string();
    let strategy = Strategy::by_name(&strategy_name)
        .ok_or_else(|| ConfigError::Invalid(format!("unknown strategy '{strategy_name}'")))?;
    let net_name = doc.str_or("cluster.network", "10gbe").to_string();
    let network = NetworkProfile::by_name(&net_name)
        .ok_or_else(|| ConfigError::Invalid(format!("unknown network '{net_name}'")))?;
    let mut hardware = NodeHardware::m2_ultra();
    hardware.mem_bw = doc.float_or("hardware.mem_bw", hardware.mem_bw);
    hardware.gpu_bf16_flops = doc.float_or("hardware.gpu_bf16_flops", hardware.gpu_bf16_flops);
    hardware.price_usd = doc.float_or("hardware.price_usd", hardware.price_usd);
    hardware.mem_efficiency = doc.float_or("hardware.mem_efficiency", hardware.mem_efficiency);

    let cluster = ClusterConfig {
        n_nodes: doc.int_or("cluster.nodes", 2) as usize,
        hardware,
        network,
        strategy,
        experts_per_node_cap: doc.int_or("cluster.experts_per_node_cap", 0) as usize,
    };

    let model_name = doc.str_or("model.name", "dbrx-132b").to_string();
    let model = ModelDims::by_name(&model_name)
        .ok_or_else(|| ConfigError::Invalid(format!("unknown model '{model_name}'")))?;
    let engine = EngineConfig {
        model,
        prompt_tokens: doc.int_or("engine.prompt_tokens", 128) as usize,
        gen_tokens: doc.int_or("engine.gen_tokens", 128) as usize,
        batch_size: doc.int_or("engine.batch_size", 1) as usize,
        seed: doc.int_or("engine.seed", 0xD8B2) as u64,
    };

    validate(&cluster, &engine)?;
    Ok((cluster, engine))
}

/// The process topology of a real (multi-process / multi-machine)
/// cluster: one `host:port` per node, in node-id order, plus the wire
/// timeouts. Loaded from a `hosts.toml`:
///
/// ```toml
/// [cluster]
/// hosts = ["10.0.0.1:7420", "10.0.0.2:7420"]
/// recv_timeout_secs = 120     # optional (default 120)
/// connect_timeout_secs = 120  # optional (default 120)
/// ```
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterHosts {
    /// `host:port` listen addresses; index = node id.
    pub hosts: Vec<String>,
    /// Bound on any single wire wait during serving.
    pub recv_timeout: Duration,
    /// How long joining nodes keep redialing peers that are not up yet.
    pub connect_timeout: Duration,
}

impl ClusterHosts {
    pub fn n_nodes(&self) -> usize {
        self.hosts.len()
    }

    pub fn load(path: &Path) -> Result<ClusterHosts, ConfigError> {
        let text = std::fs::read_to_string(path).map_err(|source| ConfigError::Io {
            path: path.display().to_string(),
            source,
        })?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<ClusterHosts, ConfigError> {
        let doc = Document::parse(text)?;
        let entries = doc
            .get("cluster.hosts")
            .and_then(Value::as_array)
            .ok_or_else(|| {
                ConfigError::Invalid(
                    "hosts.toml needs `[cluster] hosts = [\"host:port\", ...]`".into(),
                )
            })?;
        let mut hosts = Vec::with_capacity(entries.len());
        for v in entries {
            let s = v.as_str().ok_or_else(|| {
                ConfigError::Invalid(format!("cluster.hosts entries must be strings, got {v:?}"))
            })?;
            let port_ok = |p: &str| matches!(p.parse::<u16>(), Ok(port) if port > 0);
            match s.rsplit_once(':') {
                Some((host, port)) if !host.is_empty() && port_ok(port) => {}
                _ => {
                    return Err(ConfigError::Invalid(format!(
                        "bad host address '{s}' (expected host:port, port 1-65535)"
                    )))
                }
            }
            if hosts.iter().any(|h| h == s) {
                return Err(ConfigError::Invalid(format!("duplicate host address '{s}'")));
            }
            hosts.push(s.to_string());
        }
        if hosts.is_empty() {
            return Err(ConfigError::Invalid("cluster.hosts must list at least one node".into()));
        }
        let recv = doc.int_or("cluster.recv_timeout_secs", 120);
        let connect = doc.int_or("cluster.connect_timeout_secs", 120);
        if recv < 1 || connect < 1 {
            return Err(ConfigError::Invalid(
                "recv_timeout_secs / connect_timeout_secs must be >= 1".into(),
            ));
        }
        Ok(ClusterHosts {
            hosts,
            recv_timeout: Duration::from_secs(recv as u64),
            connect_timeout: Duration::from_secs(connect as u64),
        })
    }

    /// Render back to TOML (the `launch` orchestrator writes the
    /// auto-generated loopback topology for its node processes).
    pub fn render(&self) -> String {
        let hosts = self
            .hosts
            .iter()
            .map(|h| format!("\"{h}\""))
            .collect::<Vec<_>>()
            .join(", ");
        format!(
            "[cluster]\nhosts = [{hosts}]\nrecv_timeout_secs = {}\nconnect_timeout_secs = {}\n",
            self.recv_timeout.as_secs(),
            self.connect_timeout.as_secs()
        )
    }
}

/// Sanity checks shared by file and CLI construction paths.
pub fn validate(cluster: &ClusterConfig, engine: &EngineConfig) -> Result<(), ConfigError> {
    let m = &engine.model;
    if cluster.n_nodes == 0 {
        return Err(ConfigError::Invalid("cluster.nodes must be >= 1".into()));
    }
    if m.n_experts % cluster.n_nodes != 0 && cluster.experts_per_node_cap == 0 {
        // Non-divisible placements are allowed, but only with an explicit
        // overlap cap (the paper's 3-node setup loads overlappingly).
        if cluster.n_nodes > m.n_experts {
            return Err(ConfigError::Invalid(format!(
                "more nodes ({}) than experts ({})",
                cluster.n_nodes, m.n_experts
            )));
        }
    }
    if m.top_k > m.n_experts {
        return Err(ConfigError::Invalid(format!(
            "top_k {} > n_experts {}",
            m.top_k, m.n_experts
        )));
    }
    if m.d_qkv_hidden % (m.n_heads + 2 * m.n_kv_heads) != 0 {
        return Err(ConfigError::Invalid(
            "d_qkv_hidden must be divisible by n_heads + 2*n_kv_heads".into(),
        ));
    }
    if engine.batch_size == 0 || engine.gen_tokens == 0 {
        return Err(ConfigError::Invalid("batch_size/gen_tokens must be >= 1".into()));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dbrx_132b_matches_table1() {
        let m = ModelDims::dbrx_132b();
        assert_eq!(m.n_layers, 40);
        assert_eq!(m.d_embed, 6144);
        assert_eq!(m.d_qkv_hidden, 8192);
        assert_eq!(m.d_ffn, 10752);
        assert_eq!(m.n_experts, 16);
        assert_eq!(m.top_k, 4);
        assert_eq!(m.precision_bytes, 2);
        assert_eq!(m.head_dim(), 128);
    }

    #[test]
    fn nano_head_dim_consistent() {
        let m = ModelDims::dbrx_nano();
        assert_eq!(m.head_dim() * (m.n_heads + 2 * m.n_kv_heads), m.d_qkv_hidden);
    }

    #[test]
    fn network_profiles_match_paper() {
        assert_eq!(NetworkProfile::tcp_10gbe().latency_ns, 1_000_000);
        assert_eq!(NetworkProfile::rocev2().latency_ns, 750);
        assert_eq!(NetworkProfile::infiniband().latency_ns, 600);
        assert_eq!(NetworkProfile::by_name("ib").unwrap().name, "infiniband-200g");
    }

    #[test]
    fn strategy_components() {
        assert_eq!(Strategy::Naive.packing(), Packing::Unstacked);
        assert_eq!(Strategy::PLb.balancing(), Balancing::BusyFull);
        assert_eq!(Strategy::PLrD.topology(), Topology::Decentralized);
        assert_eq!(Strategy::PLb.topology(), Topology::Centralized);
        assert_eq!(Strategy::by_name("P-L_R-D"), Some(Strategy::PLrD));
        assert_eq!(format!("{}", Strategy::PLrD), "P-L_R-D");
    }

    #[test]
    fn load_defaults_from_empty() {
        let (c, e) = load_from_str("").unwrap();
        assert_eq!(c.n_nodes, 2);
        assert_eq!(c.strategy, Strategy::PLrD);
        assert_eq!(e.model.name, "dbrx-132b");
        assert_eq!(e.prompt_tokens, 128);
    }

    #[test]
    fn load_full_config() {
        let (c, e) = load_from_str(
            r#"
[cluster]
nodes = 4
strategy = "naive"
network = "rocev2"

[hardware]
mem_efficiency = 0.8

[model]
name = "dbrx-nano"

[engine]
prompt_tokens = 2000
gen_tokens = 256
"#,
        )
        .unwrap();
        assert_eq!(c.n_nodes, 4);
        assert_eq!(c.strategy, Strategy::Naive);
        assert_eq!(c.network.name, "rocev2-25g");
        assert!((c.hardware.mem_efficiency - 0.8).abs() < 1e-12);
        assert_eq!(e.model.name, "dbrx-nano");
        assert_eq!(e.prompt_tokens, 2000);
        assert_eq!(e.gen_tokens, 256);
    }

    #[test]
    fn cluster_hosts_parse_and_roundtrip() {
        let h = ClusterHosts::parse(
            r#"
[cluster]
hosts = ["10.0.0.1:7420", "10.0.0.2:7421"]
recv_timeout_secs = 30
"#,
        )
        .unwrap();
        assert_eq!(h.n_nodes(), 2);
        assert_eq!(h.hosts[1], "10.0.0.2:7421");
        assert_eq!(h.recv_timeout, Duration::from_secs(30));
        // Defaults: the old hardcoded 120 s constant.
        assert_eq!(h.connect_timeout, Duration::from_secs(120));
        let h2 = ClusterHosts::parse(&h.render()).unwrap();
        assert_eq!(h, h2);
    }

    #[test]
    fn cluster_hosts_default_timeout_is_120s() {
        let h = ClusterHosts::parse("[cluster]\nhosts = [\"127.0.0.1:7420\"]").unwrap();
        assert_eq!(h.recv_timeout, Duration::from_secs(120));
    }

    #[test]
    fn cluster_hosts_rejects_bad_input() {
        assert!(ClusterHosts::parse("").is_err());
        assert!(ClusterHosts::parse("[cluster]\nhosts = []").is_err());
        assert!(ClusterHosts::parse("[cluster]\nhosts = [\"no-port\"]").is_err());
        assert!(ClusterHosts::parse("[cluster]\nhosts = [\"h:99999\"]").is_err());
        assert!(ClusterHosts::parse("[cluster]\nhosts = [\"h:0\"]").is_err());
        assert!(ClusterHosts::parse("[cluster]\nhosts = [\"h:1\", \"h:1\"]").is_err());
        assert!(ClusterHosts::parse(
            "[cluster]\nhosts = [\"h:1\"]\nrecv_timeout_secs = 0"
        )
        .is_err());
    }

    #[test]
    fn invalid_configs_rejected() {
        assert!(load_from_str("[cluster]\nnodes = 0").is_err());
        assert!(load_from_str("[cluster]\nstrategy = \"bogus\"").is_err());
        assert!(load_from_str("[cluster]\nnetwork = \"carrier-pigeon\"").is_err());
        assert!(load_from_str("[model]\nname = \"gpt5\"").is_err());
        assert!(load_from_str("[cluster]\nnodes = 32").is_err());
        assert!(load_from_str("[engine]\ngen_tokens = 0").is_err());
    }
}
