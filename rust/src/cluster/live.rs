//! Live threaded cluster: one OS thread per simulated Mac Studio node,
//! each with its own PJRT runtime and the expert shard of Figs. 2–3,
//! exchanging expert partials over the `network::transport` fabric —
//! now behind the streaming `Engine` API with an iteration-level
//! multi-user scheduler (the paper's stated future work) running on
//! real hardware.
//!
//! Two topologies, as in the paper:
//!
//! - **Decentralized** (`D`, Fig. 7): attention, router, weighted sum and
//!   sampling are replicated on every node; the only traffic is the
//!   per-layer all-reduce of expert partials (plus deterministic
//!   replication of the sampler, which removes even the token
//!   broadcast). This is the `P-L_R-D` wire protocol.
//! - **Centralized** (Figs. 2–3): node 0 runs attention/router and
//!   scatters `moe_in` + slot assignments to workers, which run experts
//!   and send partials back — 2 communications per layer.
//!
//! # Scheduling — continuous batching
//!
//! Node 0 is the scheduler (Orca-style iteration-level scheduling,
//! ported from the virtual-time `engine::scheduler` onto real
//! hardware): every in-flight request owns its own decode state (a
//! [`DeviceState`] on the device-resident path, per-layer K/V host
//! tensors on the reference path). With the batched `dev_b{B}_*`
//! artifact family present, each scheduler iteration packs ALL active
//! requests into the smallest bucket B ∈ {2, 4, 8} that fits and runs
//! ONE shared forward pass — up to `max_active` tokens come out of one
//! iteration (continuous batching; see [`crate::runtime::BatchedRun`]).
//! Requests at different decode offsets share the dispatch via the
//! per-slot position vector; admission/completion map to slot
//! acquire/release (a slot IS the request's `DeviceState`, so bucket
//! up/downshifts never move a cache). With one active request — or on
//! the host reference path, or with pre-batching artifacts — an
//! iteration advances ONE request by ONE token as before, under the
//! configured [`SchedPolicy`] (round-robin, FCFS, or shortest-job-first
//! by remaining budget). Admission is capped at `LiveConfig::max_active`;
//! requests beyond the cap queue, and their queueing delay / TTFT /
//! end-to-end latency are metered into [`RunMetrics`], along with the
//! per-iteration batch occupancy (`PhaseMetrics::occupancy`).
//!
//! The schedule must be identical on every node of the decentralized
//! topology (they all hold per-request KV caches and replicated
//! samplers), so node 0 broadcasts each scheduling decision on a
//! control plane (`PHASE_CTRL`, ops admit/step/batch-step/cancel/
//! shutdown) that followers replay in order; the admission message
//! carries the full encoded request, so only node 0 ever needs to know
//! the workload, and the batch-step message carries the packed
//! participant list (bucket and row order derive from it
//! deterministically). Centralized workers are stateless per iteration
//! — each scatter carries its layer id, row count and a global sequence
//! number, so they need no control plane at all (an empty scatter is
//! the shutdown marker). Data-plane messages are tagged per request
//! ([`transport::req_tag`]): partials of different in-flight requests
//! demultiplex by admission sequence number (a batched iteration's
//! shared payload rides under its first row's tag).
//!
//! All coordination logic (layout, planning, LRU) is the same
//! `moe::Planner` the virtual-time DES uses. Interleaving cannot change
//! any request's tokens: selected-expert assignment is a pure function
//! of the router draw, and the planner's history-dependent padding runs
//! carry weight 0 (exact zeros in the partial sums).
//!
//! The wire protocols are written against `network::transport::Endpoint`
//! and are therefore transport-generic: `LiveCluster` runs every node as
//! a thread (on the in-process mpsc backend or, with
//! [`TransportKind::TcpLoopback`], on real loopback sockets), while
//! [`run_node`] runs ONE node's serve loop in the calling process over
//! any endpoint (the `apple-moe node` daemon hands it a `network::tcp`
//! endpoint, making the cluster span OS processes and machines).

use std::collections::{HashMap, VecDeque};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::config::{Balancing, ClusterConfig, NetworkProfile, Strategy, Topology};
use crate::engine::api::{Engine, RequestHandle, TokenEvent};
use crate::engine::request::{FinishReason, Request, RequestResult};
use crate::engine::sampling::DeviceSampleInputs;
use crate::engine::scheduler::SchedPolicy;
use crate::metrics::{RunMetrics, TokenBreakdown};
use crate::model::layout::ExpertLayout;
use crate::moe::balance::Planner;
use crate::moe::router::RouterDraw;
use crate::network::proto::StatsSnapshot;
use crate::network::transport::{
    self, bytes_to_f32s, f32s_to_bytes, req_tag, tag, Endpoint, Envelope, NetError,
};
use crate::obs;
use crate::runtime::nano::resident_index;
use crate::runtime::{BatchedRun, DeviceSample, DeviceState, HostTensor, NanoRuntime, PrefillRun};

/// Default bound on any single wire wait (`LiveConfig::recv_timeout`,
/// `[cluster] recv_timeout_secs` in hosts.toml).
pub const DEFAULT_RECV_TIMEOUT: Duration = Duration::from_secs(120);
// The PHASE_*/OP_* tag table lives in `network::tags` (single source of
// truth, fingerprinted into rust/schema.lock by `cargo xtask lint`).
pub(crate) use crate::network::tags::{
    OP_ADMIT, OP_BATCH, OP_CANCEL, OP_HEARTBEAT, OP_SHUTDOWN, OP_STEP, OP_TRACE_FLUSH, PHASE_CTRL,
    PHASE_FB, PHASE_GATHER, PHASE_PARTIAL, PHASE_SCATTER, PHASE_TRACE, SCATTER_HEARTBEAT,
    SCATTER_PREFILL_ROWS,
};

/// Poll interval while a node idles between requests (waiting for the
/// next control message or scatter). Idleness is *served* by the leader
/// heartbeat — an always-on node stays idle indefinitely as long as the
/// leader keeps proving it is alive — so this only paces shutdown and
/// deadline checks.
const IDLE_POLL: Duration = Duration::from_millis(200);

/// Which fabric backend `LiveCluster` meshes its node threads with.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TransportKind {
    /// mpsc channels between the node threads (the default emulation;
    /// supports injected `NetworkProfile` latency).
    InProcess,
    /// Real loopback TCP sockets between the node threads
    /// (`network::tcp`): the socket wire format without process spawning.
    TcpLoopback,
}

/// Live-cluster configuration.
#[derive(Debug, Clone)]
pub struct LiveConfig {
    pub artifacts: PathBuf,
    pub n_nodes: usize,
    pub topology: Topology,
    pub balancing: Balancing,
    /// Inject this profile's latency into deliveries (None = localhost;
    /// in-process transport only).
    pub network: Option<NetworkProfile>,
    /// Serve on the device-resident decode path (`DeviceState`): K/V
    /// caches and activations stay as PJRT buffers across the whole
    /// loop — zero per-layer cache round trips (§Perf). Falls back to
    /// the host-tensor reference path when the artifacts predate the
    /// `dev_*` set. `false` forces the reference path.
    pub device_resident: bool,
    /// Force the host-side reference sampler even when the artifacts
    /// carry the `dev_sample_*` roles: every iteration downloads the
    /// full `[B, V]` logits and samples on the CPU (`--host-sampler`).
    /// The default (`false`) samples on device whenever possible — the
    /// per-iteration download collapses to `[B]` token ids + `[B]`
    /// logprobs. Tokens are identical either way (the device roles
    /// mirror the host sampler op for op); keep the host path only as
    /// the audit/bisect reference, like `--host-path` for the forward.
    pub host_sampler: bool,
    /// Bound on any single wire wait (all-reduce/scatter/gather); a
    /// breach is reported with the ids of the peers that went silent.
    pub recv_timeout: Duration,
    /// Iteration-level scheduler: how many requests may hold decode
    /// state and interleave at once; submissions beyond this queue and
    /// meter real queueing delay.
    pub max_active: usize,
    /// Which in-flight request decodes next each iteration.
    pub policy: SchedPolicy,
    /// Chunked-prefill cap (`--prefill-chunk`): prompt positions are
    /// evaluated in `[T, D]` chunks of up to this many tokens per
    /// scheduler iteration — the largest compiled `dev_p{T}_*` chunk
    /// that fits, Sarathi-style: at most ONE chunk rides alongside each
    /// decode batch, so a long prompt admits without stalling everyone
    /// else's decode. `0` or `1` forces serial token-by-token prefill;
    /// the scheduler also falls back to serial when the artifacts
    /// predate the prefill family (`prefill_chunk_max = 0`).
    pub prefill_chunk: usize,
    /// Fabric backend for the node threads.
    pub transport: TransportKind,
    /// Record execution spans (`crate::obs`) on every node and, on
    /// node 0, merge them — follower buffers ship over `PHASE_TRACE` at
    /// shutdown, offset-corrected by the handshake clock sync — into one
    /// Chrome Trace Event Format JSON at this path (`--trace-out`).
    /// Followers in other processes receive the same flag and use it
    /// purely as the enable bit; only node 0 writes the file.
    pub trace: Option<PathBuf>,
}

impl LiveConfig {
    pub fn new(artifacts: PathBuf, n_nodes: usize) -> LiveConfig {
        LiveConfig {
            artifacts,
            n_nodes,
            topology: Topology::Decentralized,
            balancing: Balancing::RouterAided,
            network: None,
            device_resident: true,
            host_sampler: false,
            recv_timeout: DEFAULT_RECV_TIMEOUT,
            max_active: 2,
            policy: SchedPolicy::RoundRobin,
            prefill_chunk: 32,
            transport: TransportKind::InProcess,
            trace: None,
        }
    }

    /// How often the idle leader proves it is alive on the control
    /// plane. Derived from `recv_timeout` so several heartbeats fit in
    /// every follower's liveness window.
    pub fn heartbeat_period(&self) -> Duration {
        (self.recv_timeout / 4).clamp(Duration::from_millis(50), Duration::from_secs(5))
    }

    fn layout(&self) -> ExpertLayout {
        let strategy = match (self.topology, self.balancing) {
            (Topology::Decentralized, _) => Strategy::PLrD,
            (_, Balancing::BusyFull) => Strategy::PLb,
            _ => Strategy::Naive,
        };
        let mut cc = ClusterConfig::new(self.n_nodes, strategy);
        // The experts artifacts are compiled for 8 or 16 residents.
        cc.experts_per_node_cap = if self.n_nodes == 1 { 16 } else { 8 };
        ExpertLayout::build(&cc, &crate::config::ModelDims::dbrx_nano())
    }
}

/// A submitted-but-not-yet-admitted request (leader side).
struct Pending {
    req: Request,
    submitted: Instant,
    events: Sender<TokenEvent>,
    cancel: Arc<AtomicBool>,
}

enum Cmd {
    Submit(Box<Pending>),
    /// Ask every node to drain its trace ring mid-run: the leader
    /// relays it as `OP_TRACE_FLUSH` on the control plane and followers
    /// ship their buffers on `PHASE_TRACE` (collected by the leader's
    /// `finish_trace` stash sweep at shutdown).
    TraceFlush,
    Shutdown,
}

/// Handle to a running cluster. Implements [`Engine`]: submit requests,
/// stream their tokens, cancel mid-decode. Dropping the handle shuts
/// the cluster down (in-flight requests fail, node threads join) — so
/// early `?` returns in callers no longer leak node or reader threads.
pub struct LiveCluster {
    cmd_txs: Vec<Sender<Cmd>>,
    handles: Vec<JoinHandle<()>>,
    pub layout: ExpertLayout,
}

impl LiveCluster {
    /// Spawn node threads (each compiles its own runtime) and wait until
    /// every node reports ready.
    pub fn start(cfg: LiveConfig) -> Result<LiveCluster> {
        let layout = cfg.layout();
        let endpoints = match cfg.transport {
            TransportKind::InProcess => transport::fabric(cfg.n_nodes, cfg.network.clone()),
            TransportKind::TcpLoopback => {
                anyhow::ensure!(
                    cfg.network.is_none(),
                    "network profiles are injected by the in-process fabric only"
                );
                crate::network::tcp::loopback_fabric(cfg.n_nodes)?
            }
        };
        let (ready_tx, ready_rx) = channel();
        let mut cmd_txs = Vec::new();
        let mut handles = Vec::new();
        for (node, ep) in endpoints.into_iter().enumerate() {
            let (tx, rx) = channel();
            cmd_txs.push(tx);
            let cfg = cfg.clone();
            let layout = layout.clone();
            let ready_tx = ready_tx.clone();
            handles.push(std::thread::spawn(move || {
                let r = NodeWorker::run(node, cfg, layout, ep, rx, ready_tx);
                if let Err(e) = r {
                    log::error!("node {node} failed: {e:#}");
                }
            }));
        }
        for _ in 0..cfg.n_nodes {
            ready_rx
                .recv_timeout(Duration::from_secs(300))
                .context("node startup timed out")?
                .map_err(|e: String| anyhow::anyhow!(e))?;
        }
        Ok(LiveCluster { cmd_txs, handles, layout })
    }

    /// Submit a request to the scheduler on node 0. Returns immediately;
    /// tokens stream on the handle as they decode.
    pub fn submit(&self, req: Request) -> Result<RequestHandle> {
        anyhow::ensure!(!req.prompt.is_empty(), "request {} has an empty prompt", req.id);
        let (handle, events, cancel) = RequestHandle::channel(req.id);
        let p = Pending { req, submitted: Instant::now(), events, cancel };
        self.cmd_txs[0]
            .send(Cmd::Submit(Box::new(p)))
            .map_err(|_| anyhow::anyhow!("cluster is down (node 0 exited)"))?;
        Ok(handle)
    }

    /// Ask every node to drain its trace ring NOW instead of waiting
    /// for shutdown: node 0 relays the request to its followers as
    /// `OP_TRACE_FLUSH` on the control plane, and their shipped buffers
    /// queue on `PHASE_TRACE` until the leader's shutdown-time merge
    /// sweeps them up. A no-op unless the cluster was started with
    /// `LiveConfig::trace`. Best effort: a cluster that already exited
    /// has nothing left to flush.
    pub fn flush_trace(&self) {
        let _ = self.cmd_txs[0].send(Cmd::TraceFlush);
    }

    /// Stop the cluster: in-flight requests receive a terminal `Failed`
    /// event, followers are told to exit over the fabric, and every node
    /// thread is joined. (Also what `Drop` does.)
    pub fn shutdown(mut self) {
        self.teardown();
    }

    fn teardown(&mut self) {
        for tx in &self.cmd_txs {
            let _ = tx.send(Cmd::Shutdown);
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

impl Engine for LiveCluster {
    fn submit(&mut self, req: Request) -> Result<RequestHandle> {
        LiveCluster::submit(self, req)
    }
}

impl Drop for LiveCluster {
    fn drop(&mut self) {
        self.teardown();
    }
}

/// Run ONE node's serve loop in the calling process, over any endpoint.
///
/// This is the out-of-process twin of `LiveCluster`: the `apple-moe
/// node` daemon builds a `network::tcp` endpoint and calls this, so the
/// same wire protocols (and the same planner/runtime stack) span OS
/// processes and machines. Node 0 schedules `requests` (interleaving up
/// to `cfg.max_active` of them) and returns their results in
/// submission order; followers ignore `requests` entirely — admissions
/// arrive over the control plane with the full request aboard — and
/// return an empty vec once node 0 shuts the cluster down.
pub fn run_node(
    cfg: &LiveConfig,
    ep: Endpoint,
    requests: &[Request],
) -> Result<Vec<RequestResult>> {
    run_node_serving(cfg, ep, requests, None)
}

/// A client listener for node 0 (see [`crate::cluster::gateway`]):
/// attach it with [`run_node_serving`] and the node becomes a serving
/// daemon — remote `apple-moe client`s (or [`crate::engine::RemoteEngine`]s)
/// submit requests over the socket and stream their tokens back.
pub struct ClientServing {
    pub listener: std::net::TcpListener,
    /// Bound on a connecting client's handshake read (a
    /// connect-then-silent socket must not wedge the accept loop).
    pub handshake_timeout: Duration,
}

impl ClientServing {
    pub fn new(listener: std::net::TcpListener) -> ClientServing {
        ClientServing {
            listener,
            handshake_timeout: crate::cluster::gateway::DEFAULT_CLIENT_HANDSHAKE_TIMEOUT,
        }
    }
}

/// [`run_node`] with an optional client listener on node 0.
///
/// With `clients` attached, node 0 keeps serving after the local
/// `requests` drain: any number of remote connections multiplex into
/// the same scheduler queue (their streams are token-identical to an
/// in-process `submit`), and the daemon exits when a client sends the
/// administrative shutdown (`apple-moe client --shutdown`). A client
/// that vanishes mid-stream self-cancels at the next scheduler sweep,
/// freeing its `max_active` slot for everyone else.
pub fn run_node_serving(
    cfg: &LiveConfig,
    ep: Endpoint,
    requests: &[Request],
    clients: Option<ClientServing>,
) -> Result<Vec<RequestResult>> {
    anyhow::ensure!(
        ep.n_nodes() == cfg.n_nodes,
        "endpoint is attached to a {}-node fabric but the config says {} nodes",
        ep.n_nodes(),
        cfg.n_nodes
    );
    let node = ep.node();
    anyhow::ensure!(
        node == 0 || clients.is_none(),
        "only node 0 (the scheduler) can serve remote clients"
    );
    let layout = cfg.layout();
    let mut w = NodeWorker::new(node, cfg.clone(), layout, ep)?;
    if node != 0 {
        w.follow(None)?;
        w.ship_trace();
        return Ok(Vec::new());
    }
    // Node 0: drive the scheduler over a local queue. Everything runs on
    // this thread, so the event streams buffer in their (unbounded)
    // channels and are drained into results afterwards.
    //
    // The gateway slot is declared BEFORE the channel: locals unwind in
    // reverse declaration order, so a panic inside `lead` drops `rx`
    // (and with it any queued submissions' event senders) before the
    // gateway's Drop joins forwarder threads — the same join-deadlock
    // hazard the explicit `drop(rx)` below closes on the error path.
    let mut gateway: Option<crate::cluster::gateway::ClientGateway> = None;
    let (tx, rx) = channel();
    let mut event_rxs = Vec::with_capacity(requests.len());
    for req in requests {
        anyhow::ensure!(!req.prompt.is_empty(), "request {} has an empty prompt", req.id);
        let (handle, events, cancel) = RequestHandle::channel(req.id);
        event_rxs.push((req.id, handle));
        tx.send(Cmd::Submit(Box::new(Pending {
            req: req.clone(),
            submitted: Instant::now(),
            events,
            cancel,
        })))
        .expect("local queue open");
    }
    match clients {
        None => {}
        Some(c) => {
            // The gateway's submit closure is the remote twin of
            // `LiveCluster::submit`; its Sender clones keep the command
            // channel (and therefore the serve loop) open until the
            // gateway stops — that is what makes this a daemon.
            let submit_tx = tx.clone();
            let submit = move |req: Request| -> Result<RequestHandle> {
                let (handle, events, cancel) = RequestHandle::channel(req.id);
                submit_tx
                    .send(Cmd::Submit(Box::new(Pending {
                        req,
                        submitted: Instant::now(),
                        events,
                        cancel,
                    })))
                    .map_err(|_| anyhow::anyhow!("cluster is shutting down"))?;
                Ok(handle)
            };
            let hello = crate::network::proto::ServerHello {
                n_nodes: cfg.n_nodes as u32,
                max_active: cfg.max_active.max(1) as u32,
            };
            // Live `--stats` pulls read whatever the scheduler loop
            // last published (same thread as `lead`, so the snapshot is
            // always from a consistent iteration boundary).
            let live = w.live_stats.clone();
            let stats: crate::cluster::gateway::StatsProvider =
                Arc::new(move || live.lock().expect("live stats").clone());
            let gw = crate::cluster::gateway::ClientGateway::start(
                c.listener,
                hello,
                c.handshake_timeout,
                submit,
                stats,
            )?;
            log::info!("node 0: serving remote clients on {}", gw.local_addr());
            gateway = Some(gw);
        }
    }
    drop(tx); // without clients the leader exits once the local queue drains
    let served = w.lead(&rx);
    // On the error path, submissions may still be queued in the channel;
    // dropping the receiver drops their event senders, so the gateway's
    // forwarder threads (joined below) observe end-of-stream instead of
    // blocking forever.
    drop(rx);
    if let Some(gw) = gateway {
        // Normal exit means a client's Shutdown stopped the gateway
        // first; on the error path this force-stops it so connection
        // threads unblock. Either way the accounting comes home.
        let stats = gw.finish();
        log::info!(
            "client gateway: {} connection(s), {} remote request(s), \
             sent {} msgs / {} B, recv {} msgs / {} B",
            stats.connections,
            stats.requests,
            stats.link.sent_msgs,
            stats.link.sent_bytes,
            stats.link.recv_msgs,
            stats.link.recv_bytes
        );
    }
    served?;
    w.finish_trace();
    let mut out = Vec::with_capacity(event_rxs.len());
    for (id, handle) in event_rxs {
        let mut result = None;
        while let Some(ev) = handle.try_event() {
            match ev {
                TokenEvent::Done { result: r } => result = Some(r),
                TokenEvent::Failed { error, .. } => {
                    anyhow::bail!("request {id} failed: {error}")
                }
                _ => {}
            }
        }
        out.push(result.ok_or_else(|| anyhow::anyhow!("request {id} never completed"))?);
    }
    Ok(out)
}

/// Per-request decode state: a device-resident `DeviceState` or the
/// host-tensor reference caches. One per in-flight request; dropped
/// (freeing the buffers) the moment the request finishes or cancels.
enum DecodeState {
    Dev(DeviceState),
    Host { kc: Vec<HostTensor>, vc: Vec<HostTensor> },
}

/// One in-flight request on a node.
struct ActiveRequest {
    req: Request,
    /// Admission sequence number: demultiplexes this request's
    /// data-plane traffic (`req_tag`) and names it on the control plane.
    seq: u16,
    state: DecodeState,
    pos: usize,
    step: u32,
    /// The token the device sampler drew at the end of the last forward
    /// pass, waiting for the next iteration's Phase A to record it.
    /// `None` on the host-sampler path (Phase A then samples from
    /// `last_logits`). Identical on every replicated-sampling node:
    /// sampling is stateless, keyed on `(req.sampling.seed, pos)`.
    pending_sample: Option<DeviceSample>,
    /// The last iteration's `[V]` logits — populated only on the
    /// host-sampler path (on the device-sampler path logits never cross
    /// the host boundary; this stays empty).
    last_logits: Vec<f32>,
    generated: Vec<u32>,
    metrics: RunMetrics,
    finish: Option<FinishReason>,
    // Leader-side serving-surface state (None on followers).
    submitted: Option<Instant>,
    first_token: Option<Instant>,
    events: Option<Sender<TokenEvent>>,
    cancel: Option<Arc<AtomicBool>>,
}

fn emit_done(a: ActiveRequest, finish: FinishReason) {
    let ActiveRequest { req, generated, mut metrics, events, submitted, .. } = a;
    if let Some(s) = submitted {
        metrics.latency_ns = s.elapsed().as_nanos() as u64;
    }
    let result = RequestResult { id: req.id, generated, finish, metrics };
    if let Some(ev) = events {
        let _ = ev.send(TokenEvent::Done { result });
    }
}

/// Stream one sampled token on the request's handle (no-op on
/// followers, whose requests carry no sender): `Started` with the
/// measured TTFT precedes the first token; a dropped handle self-cancels
/// so the next scheduler sweep frees the decode state.
fn emit_token(a: &mut ActiveRequest, tok: u32, lp: f32) {
    if a.first_token.is_none() {
        a.first_token = Some(Instant::now());
        if let Some(s) = a.submitted {
            a.metrics.ttft_ns = s.elapsed().as_nanos() as u64;
        }
        if let Some(ev) = &a.events {
            let _ = ev.send(TokenEvent::Started {
                ttft_s: a.metrics.ttft_ns as f64 / 1e9,
                queued_s: a.metrics.queueing_ns as f64 / 1e9,
            });
        }
    }
    if let Some(ev) = &a.events {
        if ev.send(TokenEvent::Token { id: tok, logprob: Some(lp) }).is_err() {
            // The handle was dropped without cancel(): nobody can
            // observe this stream. Self-cancel so the next sweep frees
            // the decode state (and tells followers).
            if let Some(c) = &a.cancel {
                c.store(true, Ordering::Relaxed);
            }
        }
    }
}

fn emit_failed(a: &ActiveRequest, error: &str) {
    if let Some(ev) = &a.events {
        let _ = ev.send(TokenEvent::Failed { id: a.req.id, error: error.to_string() });
    }
}

fn fail_pending(p: &Pending, error: &str) {
    let _ = p
        .events
        .send(TokenEvent::Failed { id: p.req.id, error: error.to_string() });
}

struct NodeWorker {
    node: usize,
    cfg: LiveConfig,
    rt: NanoRuntime,
    experts: crate::runtime::NodeExperts,
    planner: Planner,
    /// Global→local expert maps per node (the centralized leader maps
    /// remote peers' slot assignments without linear scans).
    peer_index: Vec<HashMap<usize, usize>>,
    ep: Endpoint,
    /// Control-plane sequence number (leader increments per broadcast,
    /// followers per replayed message).
    ctrl_seq: u32,
    /// Centralized topology: global scatter/gather sequence number (one
    /// per (request, layer) iteration, shared leader/workers).
    wseq: u32,
    /// Follower side: the periodic liveness beacon to node 0 (None on
    /// the leader and on single-node clusters).
    beacon: Option<Beacon>,
    /// Leader side: when each follower last proved it was alive (a
    /// beacon while idle, or any completed gather). Checked against
    /// `recv_timeout` only while the leader idles.
    followers_heard: Vec<Instant>,
    /// Leader side: the snapshot a gateway `--stats` pull reads. The
    /// scheduler republishes occupancy/queue depth each iteration and
    /// folds each finished request's decode metrics in, so the admin
    /// frame never has to interrupt the serve loop.
    live_stats: Arc<Mutex<StatsSnapshot>>,
}

impl NodeWorker {
    /// Load this node's runtime + expert shard and attach the endpoint.
    fn new(node: usize, cfg: LiveConfig, layout: ExpertLayout, ep: Endpoint) -> Result<NodeWorker> {
        if cfg.trace.is_some() {
            obs::enable();
            obs::set_track(node, if node == 0 { "scheduler" } else { "worker" });
        }
        let rt = NanoRuntime::load(&cfg.artifacts, false)?;
        if cfg.device_resident && !rt.has_device_path() {
            log::warn!(
                "node {node}: artifacts lack the dev_* set — serving on the \
                 host-tensor reference path (re-run `make artifacts`)"
            );
        }
        let experts = rt.build_node_experts(&layout.resident[node])?;
        let peer_index = layout.resident.iter().map(|r| resident_index(r)).collect();
        let planner = Planner::new(cfg.balancing, layout);
        let beacon = if node != 0 && ep.n_nodes() > 1 {
            Some(Beacon::new(node, cfg.heartbeat_period()))
        } else {
            None
        };
        let followers_heard = vec![Instant::now(); ep.n_nodes()];
        Ok(NodeWorker {
            node,
            cfg,
            rt,
            experts,
            planner,
            peer_index,
            ep,
            ctrl_seq: 0,
            wseq: 0,
            beacon,
            followers_heard,
            live_stats: Arc::new(Mutex::new(StatsSnapshot::default())),
        })
    }

    fn run(
        node: usize,
        cfg: LiveConfig,
        layout: ExpertLayout,
        ep: Endpoint,
        rx: Receiver<Cmd>,
        ready_tx: Sender<std::result::Result<(), String>>,
    ) -> Result<()> {
        let mut w = match NodeWorker::new(node, cfg, layout, ep) {
            Ok(w) => {
                let _ = ready_tx.send(Ok(()));
                w
            }
            Err(e) => {
                let _ = ready_tx.send(Err(format!("{e:#}")));
                return Err(e);
            }
        };
        if node == 0 {
            w.lead(&rx)?;
            w.finish_trace();
        } else {
            w.follow(Some(&rx))?;
            w.ship_trace();
        }
        Ok(())
    }

    fn use_device(&self) -> bool {
        self.cfg.device_resident && self.rt.has_device_path()
    }

    /// This request samples on device: device-resident state, sampler
    /// artifacts present, not forced off (`--host-sampler`), and the
    /// request's parameters fit the artifact operand widths. Every
    /// input is replicated (config, manifest, request), so all
    /// decentralized nodes take the same branch.
    fn use_device_sampler(&self, a: &ActiveRequest) -> bool {
        !self.cfg.host_sampler
            && matches!(a.state, DecodeState::Dev(_))
            && self.rt.has_sampler_path()
            && a.req.sampling.device_compatible(
                self.rt.manifest.sampler_max_top_k,
                self.rt.manifest.sampler_max_stop,
            )
    }

    /// Will the iteration AFTER this forward pass sample a token? False
    /// during prefill (bar the last prompt position) and once the
    /// request is certain to finish on length — the device sampler is
    /// then skipped entirely, which also skips lm_head: prefill
    /// iterations stop paying for logits nobody reads.
    fn will_sample(&self, a: &ActiveRequest) -> bool {
        a.pos + 1 >= a.req.prompt.len()
            && a.pos + 1 < self.rt.manifest.max_seq
            && a.generated.len() < a.req.sampling.max_new_tokens
    }

    /// Allocate decode state and book-keeping for a newly admitted
    /// request.
    fn admit(
        &self,
        req: Request,
        seq: u16,
        submitted: Option<Instant>,
        events: Option<Sender<TokenEvent>>,
        cancel: Option<Arc<AtomicBool>>,
    ) -> Result<ActiveRequest> {
        let state = if self.use_device() {
            DecodeState::Dev(DeviceState::new(&self.rt)?)
        } else {
            let kc: Vec<HostTensor> = (0..self.rt.manifest.n_layers)
                .map(|_| self.rt.empty_layer_cache())
                .collect();
            let vc = kc.clone();
            DecodeState::Host { kc, vc }
        };
        Ok(ActiveRequest {
            req,
            seq,
            state,
            pos: 0,
            step: 0,
            pending_sample: None,
            last_logits: Vec::new(),
            generated: Vec::new(),
            metrics: RunMetrics::default(),
            finish: None,
            submitted,
            first_token: None,
            events,
            cancel,
        })
    }

    // ---------------- leader: the iteration-level scheduler ----------

    /// Node 0's serve loop: pump submissions, admit up to `max_active`
    /// (admission order set by the policy), run one scheduler iteration
    /// — continuously batched (all active requests share one forward)
    /// or serial batch-1 — stream events, and replicate every decision
    /// to the followers. Exits when told to shut down, or when the
    /// command channel closes and all work has drained. On error — a
    /// wire or runtime failure dooms the whole schedule, since peers
    /// are mid-protocol — everything in flight gets a terminal `Failed`
    /// event and the followers are told to exit before bubbling up.
    fn lead(&mut self, rx: &Receiver<Cmd>) -> Result<()> {
        let _run_sp = obs::span("run");
        let mut pending: VecDeque<Pending> = VecDeque::new();
        let mut active: Vec<ActiveRequest> = Vec::new();
        let r = self.lead_loop(rx, &mut pending, &mut active);
        if let Err(e) = &r {
            let msg = format!("{e:#}");
            for a in active.drain(..) {
                emit_failed(&a, &msg);
            }
            for p in pending.drain(..) {
                fail_pending(&p, &msg);
            }
            let _ = self.broadcast_shutdown();
        }
        r
    }

    fn lead_loop(
        &mut self,
        rx: &Receiver<Cmd>,
        pending: &mut VecDeque<Pending>,
        active: &mut Vec<ActiveRequest>,
    ) -> Result<()> {
        let mut next_seq: u16 = 0;
        let mut rr: usize = 0;
        let mut open = true;

        // First heartbeat up front: followers bound their idle waits on
        // leader traffic, so the leader announces itself the moment its
        // serve loop is up (not a heartbeat period later).
        self.heartbeat();

        loop {
            // 1. Pump commands: block when idle, drain without blocking
            //    while requests are in flight.
            loop {
                let cmd = if open && active.is_empty() && pending.is_empty() {
                    // Idle: block for the next submission, waking every
                    // heartbeat period to prove liveness to the
                    // followers (they bound their idle waits on it).
                    match rx.recv_timeout(self.cfg.heartbeat_period()) {
                        Ok(c) => Some(c),
                        Err(RecvTimeoutError::Timeout) => {
                            self.heartbeat();
                            None
                        }
                        Err(RecvTimeoutError::Disconnected) => {
                            open = false;
                            None
                        }
                    }
                } else if open {
                    match rx.try_recv() {
                        Ok(c) => Some(c),
                        Err(TryRecvError::Empty) => None,
                        Err(TryRecvError::Disconnected) => {
                            open = false;
                            None
                        }
                    }
                } else {
                    None
                };
                match cmd {
                    Some(Cmd::Submit(p)) => pending.push_back(*p),
                    Some(Cmd::TraceFlush) => {
                        // Relay to the followers (decentralized control
                        // plane; centralized workers carry no trace
                        // ring worth flushing mid-run — their buffers
                        // ship at shutdown). Best effort, like the
                        // heartbeat: tracing must never kill a serve
                        // loop.
                        if self.cfg.topology == Topology::Decentralized {
                            let _ = self.ctrl(OP_TRACE_FLUSH, &[]);
                        }
                    }
                    Some(Cmd::Shutdown) => {
                        for p in pending.drain(..) {
                            fail_pending(&p, "cluster shut down");
                        }
                        for a in active.drain(..) {
                            emit_failed(&a, "cluster shut down");
                        }
                        // Best effort: a follower that already honoured
                        // its own shutdown command has dropped its
                        // endpoint, and that must not fail a clean exit.
                        let _ = self.broadcast_shutdown();
                        return Ok(());
                    }
                    None => break,
                }
            }
            // Symmetric liveness: drain the followers' idle beacons and
            // bound their silence. The loop passes through here once
            // per heartbeat period while idle and once per iteration
            // while serving (where every gather refreshes the
            // deadlines, so only a truly silent follower can trip it).
            self.check_followers()?;
            if !open && active.is_empty() && pending.is_empty() {
                // All submitters are gone and the work has drained: a
                // clean end of service (the `run_node` path). Followers
                // must learn about it, so this send IS load-bearing.
                self.broadcast_shutdown()?;
                return Ok(());
            }

            // 2. Cancellation sweep — pending first (never admitted),
            //    then active (frees their decode state; followers drop
            //    theirs via OP_CANCEL).
            let mut i = 0;
            while i < pending.len() {
                if pending[i].cancel.load(Ordering::Relaxed) {
                    let p = pending.remove(i).expect("index in bounds");
                    let waited = p.submitted.elapsed().as_nanos() as u64;
                    let metrics = RunMetrics {
                        queueing_ns: waited,
                        latency_ns: waited,
                        ..Default::default()
                    };
                    let _ = p.events.send(TokenEvent::Done {
                        result: RequestResult {
                            id: p.req.id,
                            generated: Vec::new(),
                            finish: FinishReason::Cancelled,
                            metrics,
                        },
                    });
                } else {
                    i += 1;
                }
            }
            let mut i = 0;
            while i < active.len() {
                let cancelled =
                    active[i].cancel.as_ref().is_some_and(|c| c.load(Ordering::Relaxed));
                if cancelled {
                    let a = active.remove(i);
                    if self.cfg.topology == Topology::Decentralized {
                        self.ctrl(OP_CANCEL, &a.seq.to_le_bytes())?;
                    }
                    self.book_finished(&a);
                    emit_done(a, FinishReason::Cancelled);
                } else {
                    i += 1;
                }
            }

            // 3. Admission up to the concurrency cap (SJF admits the
            //    smallest generation budget first; other policies admit
            //    in arrival order).
            while active.len() < self.cfg.max_active.max(1) {
                let idx = match self.cfg.policy {
                    SchedPolicy::ShortestJobFirst => pending
                        .iter()
                        .enumerate()
                        .min_by_key(|(_, p)| p.req.sampling.max_new_tokens)
                        .map(|(i, _)| i),
                    _ => {
                        if pending.is_empty() {
                            None
                        } else {
                            Some(0)
                        }
                    }
                };
                let Some(idx) = idx else { break };
                let p = pending.remove(idx).expect("index in bounds");
                let seq = next_seq;
                next_seq = next_seq.wrapping_add(1);
                if self.cfg.topology == Topology::Decentralized {
                    let mut body = p.req.encode();
                    let mut framed = seq.to_le_bytes().to_vec();
                    framed.append(&mut body);
                    self.ctrl(OP_ADMIT, &framed)?;
                }
                let Pending { req, submitted, events, cancel } = p;
                let mut a =
                    self.admit(req, seq, Some(submitted), Some(events), Some(cancel))?;
                a.metrics.queueing_ns = submitted.elapsed().as_nanos() as u64;
                active.push(a);
            }
            self.publish_stats(active.len(), pending.len());
            if active.is_empty() {
                continue;
            }
            let _sp = obs::span("sched.iteration").arg("active", active.len() as u64);

            // 4. One iteration. Mixed prefill/decode (Sarathi-style):
            //    at most ONE prefill chunk — from the longest-waiting
            //    admitted prompt — rides alongside the decode batch, so
            //    a long prompt's positions share each layer's dispatch
            //    train instead of paying it per token, while everyone
            //    else's decode still advances every iteration.
            //    Continuous batching: the remaining active requests
            //    advance together through ONE shared forward pass (the
            //    participant list + prefill descriptor replicate to
            //    followers). Serial fallback (one decode-phase request,
            //    host path, or pre-chunking artifacts): pick one
            //    request under the policy and advance it one token.
            let pre = self.select_prefill(active);
            if pre.is_some() || self.batched_ok(active) {
                if self.cfg.topology == Topology::Decentralized {
                    let pi = pre.map(|(i, _, _)| i);
                    let decoders = active.len() - pi.is_some() as usize;
                    let mut body = (decoders as u16).to_le_bytes().to_vec();
                    for (i, a) in active.iter().enumerate() {
                        if Some(i) != pi {
                            body.extend_from_slice(&a.seq.to_le_bytes());
                        }
                    }
                    if let Some((i, chunk, real)) = pre {
                        body.extend_from_slice(&active[i].seq.to_le_bytes());
                        body.extend_from_slice(&(chunk as u16).to_le_bytes());
                        body.extend_from_slice(&(real as u16).to_le_bytes());
                    }
                    self.ctrl(OP_BATCH, &body)?;
                }
                if let Some((i, chunk, real)) = pre {
                    self.prefill_chunk_step(&mut active[i], chunk, real)?;
                }
                self.batch_iteration(active, pre.map(|(i, _, _)| i))?;
                let mut i = 0;
                while i < active.len() {
                    if active[i].finish.is_some() {
                        let a = active.remove(i);
                        let finish = a.finish.expect("checked above");
                        self.book_finished(&a);
                        emit_done(a, finish);
                    } else {
                        i += 1;
                    }
                }
            } else {
                let i = match self.cfg.policy {
                    SchedPolicy::RoundRobin => rr % active.len(),
                    SchedPolicy::RunToCompletion => 0,
                    SchedPolicy::ShortestJobFirst => active
                        .iter()
                        .enumerate()
                        .min_by_key(|(_, a)| {
                            a.req.sampling.max_new_tokens.saturating_sub(a.generated.len())
                        })
                        .map(|(i, _)| i)
                        .unwrap_or(0),
                };
                rr = rr.wrapping_add(1);
                self.lead_one(&mut active[i])?;
                if active[i].finish.is_some() {
                    let a = active.remove(i);
                    let finish = a.finish.expect("checked above");
                    self.book_finished(&a);
                    emit_done(a, finish);
                }
            }
        }
    }

    /// Republish the snapshot a gateway `Stats` pull reads: scheduler
    /// occupancy, queue depth and the cumulative per-peer link totals.
    /// Runs once per scheduler pass on the serve thread, so a pull
    /// always sees a consistent iteration boundary.
    fn publish_stats(&self, active: usize, queued: usize) {
        let mut s = self.live_stats.lock().expect("live stats");
        s.active = active as u32;
        s.queued = queued as u32;
        s.mesh_links = self.ep.peer_totals().to_vec();
    }

    /// Fold a finished request's decode-phase metrics into the live
    /// snapshot (Welford moments and tail histograms both merge).
    fn book_finished(&self, a: &ActiveRequest) {
        let mut s = self.live_stats.lock().expect("live stats");
        s.decode.merge(&a.metrics.decode);
    }

    /// Follower side: drain this node's trace ring and ship it to
    /// node 0 over `PHASE_TRACE`. Best effort — tracing must never fail
    /// a clean shutdown — and a no-op when tracing is off.
    fn ship_trace(&mut self) {
        if self.cfg.trace.is_none() || self.node == 0 {
            return;
        }
        let events = obs::drain_node(self.node);
        let payload = obs::encode_events(&events);
        if let Err(e) = self.ep.send(0, tag(PHASE_TRACE, self.node as u32, 0), payload) {
            log::warn!(
                "node {}: could not ship {} trace events to the leader: {e}",
                self.node,
                events.len()
            );
        }
    }

    /// Leader side: collect every node's trace buffer — the local ring
    /// drained directly, follower rings shipped over `PHASE_TRACE` at
    /// shutdown — map each onto node 0's timeline with the handshake
    /// clock offsets, and write ONE merged Chrome Trace Event Format
    /// JSON. Best effort: a missing follower buffer is logged, never
    /// fatal, so a trace always comes out of whatever survived.
    fn finish_trace(&mut self) {
        let Some(path) = self.cfg.trace.clone() else { return };
        let own = obs::drain_node(self.node);
        let mut groups: Vec<(i64, Vec<obs::WireEvent>)> =
            vec![(0, own.iter().map(obs::WireEvent::from).collect())];
        for peer in 1..self.ep.n_nodes() {
            let t = tag(PHASE_TRACE, peer as u32, 0);
            let mut evs = Vec::new();
            match self.ep.recv_tag(t, Duration::from_secs(5)) {
                Ok(env) => match obs::decode_events(&env.payload) {
                    Ok(mut v) => evs.append(&mut v),
                    Err(e) => log::warn!("node {peer}: undecodable trace buffer: {e:#}"),
                },
                Err(e) => log::warn!("node {peer}: no trace buffer at shutdown: {e}"),
            }
            // A mid-run OP_TRACE_FLUSH may have queued earlier
            // shipments; sweep the stash without blocking.
            while let Ok(env) = self.ep.recv_tag(t, Duration::ZERO) {
                match obs::decode_events(&env.payload) {
                    Ok(mut v) => evs.append(&mut v),
                    Err(e) => log::warn!("node {peer}: undecodable trace buffer: {e:#}"),
                }
            }
            groups.push((self.ep.clock_offset_ns(peer), evs));
        }
        let n: usize = groups.iter().map(|(_, g)| g.len()).sum();
        let json = obs::chrome_trace_json(&groups);
        match std::fs::write(&path, json) {
            Ok(()) => log::info!("trace: wrote {n} events to {}", path.display()),
            Err(e) => log::warn!("trace: could not write {}: {e}", path.display()),
        }
    }

    /// The continuous-batching iteration applies: >1 active request on
    /// the device-resident path with the batched artifact family
    /// present. (A lone request decodes serially — the bucket floor —
    /// and the host reference path always decodes serially.)
    fn batched_ok(&self, active: &[ActiveRequest]) -> bool {
        active.len() > 1 && self.use_device() && self.rt.has_batched_path()
    }

    /// Pick this iteration's prefill chunk (Sarathi-style mixed
    /// iterations): at most ONE chunk per iteration, from the
    /// longest-waiting admitted prompt (`active` is admission-ordered,
    /// so the first mid-prompt request wins). Returns
    /// `(index, chunk, real_rows)` — the largest compiled `dev_p{T}_*`
    /// chunk that fits the remaining prompt and the `--prefill-chunk`
    /// cap, padding the smallest chunk for short tails.
    ///
    /// Only positions `0..prompt.len()-1` ever enter a chunk: the LAST
    /// prompt token always runs on the decode path, whose forward
    /// produces logits and samples — which is what keeps chunked
    /// prefill bit-identical to serial (the chunk only appends K/V).
    fn select_prefill(&self, active: &[ActiveRequest]) -> Option<(usize, usize, usize)> {
        if self.cfg.prefill_chunk < 2 || !self.use_device() || !self.rt.has_prefill_path() {
            return None;
        }
        let smallest = *self.rt.manifest.prefill_chunks().first()?;
        for (i, a) in active.iter().enumerate() {
            if a.finish.is_some() || !matches!(a.state, DecodeState::Dev(_)) {
                continue;
            }
            // Chunkable prompt positions left (last prompt token
            // excluded — it decodes).
            let remaining = a.req.prompt.len().saturating_sub(1).saturating_sub(a.pos);
            if remaining < 2 {
                continue; // a lone position is cheaper serial than padded
            }
            let cap = self.cfg.prefill_chunk.min(remaining);
            let chunk = self.rt.prefill_chunk_for(cap).unwrap_or(smallest);
            if a.pos + chunk > self.rt.manifest.max_seq {
                continue; // no room to pad near max_seq: serial steps
            }
            let real = remaining.min(chunk).min(self.cfg.prefill_chunk);
            return Some((i, chunk, real));
        }
        None
    }

    /// Replicate the step decision (decentralized) and run it locally,
    /// streaming the sampled token to the request's handle.
    fn lead_one(&mut self, a: &mut ActiveRequest) -> Result<()> {
        if self.cfg.topology == Topology::Decentralized {
            self.ctrl(OP_STEP, &a.seq.to_le_bytes())?;
        }
        self.step(a)
    }

    /// Broadcast one scheduling decision to the followers (decentralized
    /// topology; centralized workers are driven by the scatter stream).
    ///
    /// The sequence number advances even when the broadcast errors
    /// (matching `next_wseq` on the centralized plane): a partial
    /// broadcast — delivered to some followers, failed on a dead one —
    /// must not make the leader re-tag its next message with a number
    /// the survivors already consumed, or they would desync and read a
    /// live leader as lost.
    fn ctrl(&mut self, op: u8, body: &[u8]) -> Result<()> {
        let mut payload = Vec::with_capacity(1 + body.len());
        payload.push(op);
        payload.extend_from_slice(body);
        let t = tag(PHASE_CTRL, 0, self.ctrl_seq);
        self.ctrl_seq = self.ctrl_seq.wrapping_add(1);
        self.ep.broadcast(t, &payload)?;
        Ok(())
    }

    /// Prove liveness to the followers while idle. Best-effort: a send
    /// failure here either races a legitimate teardown (followers
    /// already exited) or precedes a hard error the next real control
    /// message will surface — neither should kill an idle leader.
    fn heartbeat(&mut self) {
        match self.cfg.topology {
            Topology::Decentralized => {
                let _ = self.ctrl(OP_HEARTBEAT, &[]);
            }
            Topology::Centralized => {
                if let Some(w) = self.next_wseq() {
                    let _ = self.ep.broadcast(tag(PHASE_SCATTER, 0, w), &[SCATTER_HEARTBEAT]);
                }
            }
        }
    }

    /// Leader-side symmetric liveness: drain the followers' idle
    /// beacons, then error with the silent node ids once any follower
    /// has gone `recv_timeout` without proving it is alive (beacon or
    /// completed gather). Called only from the idle loop — while the
    /// cluster serves, every all-reduce/gather already bounds follower
    /// silence and refreshes the deadlines via
    /// [`NodeWorker::note_followers_alive`].
    fn check_followers(&mut self) -> Result<()> {
        if self.node != 0 || self.ep.n_nodes() == 1 {
            return Ok(());
        }
        for f in 1..self.ep.n_nodes() {
            while self.ep.recv_tag(beacon_tag(f), Duration::ZERO).is_ok() {
                self.followers_heard[f] = Instant::now();
            }
        }
        let bound = self.cfg.recv_timeout;
        let missing: Vec<usize> = (1..self.ep.n_nodes())
            .filter(|&f| self.followers_heard[f].elapsed() > bound)
            .collect();
        if missing.is_empty() {
            Ok(())
        } else {
            Err(NetError::FollowerLost(missing, bound).into())
        }
    }

    /// Every peer just delivered a gather: all followers are provably
    /// alive right now (the idle-time beacon deadlines restart here, so
    /// a busy stretch can never read as follower silence).
    fn note_followers_alive(&mut self) {
        let now = Instant::now();
        for t in &mut self.followers_heard {
            *t = now;
        }
    }

    fn broadcast_shutdown(&mut self) -> Result<()> {
        match self.cfg.topology {
            Topology::Decentralized => self.ctrl(OP_SHUTDOWN, &[]),
            Topology::Centralized => {
                // Workers wait on the scatter stream: an empty scatter at
                // the next global sequence number ends them.
                let w = self.wseq;
                self.wseq = self.wseq.wrapping_add(1);
                self.ep.broadcast(tag(PHASE_SCATTER, 0, w), &[])?;
                Ok(())
            }
        }
    }

    // ---------------- followers ----------

    fn follow(&mut self, rx: Option<&Receiver<Cmd>>) -> Result<()> {
        match self.cfg.topology {
            Topology::Decentralized => self.follow_decentralized(rx),
            Topology::Centralized => self.follow_central_worker(rx),
        }
    }

    /// Idle-tolerant wait for the next message on `t`, bounded by the
    /// leader's liveness: the idle leader heartbeats every
    /// [`LiveConfig::heartbeat_period`], so `recv_timeout` without ANY
    /// leader traffic means node 0 is gone — the follower exits with
    /// [`NetError::LeaderLost`] instead of idling forever. (Before this
    /// bound, a TCP follower in a >2-node mesh whose leader died
    /// mid-idle only noticed when ALL its peers hung up, because the
    /// surviving followers' connections kept the fabric channel open.)
    /// Also checks the local command channel — when one exists — so an
    /// in-process cluster can always shut its followers down; returns
    /// `None` on local shutdown.
    ///
    /// The bound also covers the follower's FIRST wait, so node-to-node
    /// startup skew (runtime compile times) must stay under
    /// `recv_timeout`; the leader heartbeats immediately when its serve
    /// loop comes up to keep that window as wide as possible.
    fn recv_or_shutdown(
        &mut self,
        t: u64,
        rx: Option<&Receiver<Cmd>>,
    ) -> Result<Option<Envelope>> {
        let Some(rx) = rx else {
            // Out-of-process follower (the `apple-moe node` daemon):
            // no local channel, the leader bound is the only exit.
            return Ok(Some(recv_from_leader(
                &mut self.ep,
                t,
                self.cfg.recv_timeout,
                IDLE_POLL,
                self.beacon.as_mut(),
            )?));
        };
        let deadline = Instant::now() + self.cfg.recv_timeout;
        loop {
            loop {
                match rx.try_recv() {
                    Ok(Cmd::Shutdown) => return Ok(None),
                    Ok(Cmd::Submit(p)) => {
                        // Followers never schedule; a stray submit is
                        // failed rather than silently dropped.
                        fail_pending(&p, "submitted to a follower node");
                    }
                    // `LiveCluster::flush_trace` targets node 0, which
                    // relays `OP_TRACE_FLUSH` over the control plane;
                    // a follower handed the command directly just
                    // ships its own ring.
                    Ok(Cmd::TraceFlush) => self.ship_trace(),
                    Err(TryRecvError::Empty) => break,
                    Err(TryRecvError::Disconnected) => return Ok(None),
                }
            }
            if let Some(b) = self.beacon.as_mut() {
                b.tick(&mut self.ep);
            }
            match self.ep.recv_tag(t, IDLE_POLL) {
                Ok(env) => return Ok(Some(env)),
                Err(NetError::Timeout(_)) => {
                    if Instant::now() >= deadline {
                        return Err(NetError::LeaderLost(self.cfg.recv_timeout).into());
                    }
                }
                Err(e) => return Err(e.into()),
            }
        }
    }

    /// Decentralized follower: replay node 0's control plane in order —
    /// admissions (full request aboard), steps (replicated compute +
    /// sampling), cancellations (drop the request's decode state).
    fn follow_decentralized(&mut self, rx: Option<&Receiver<Cmd>>) -> Result<()> {
        let mut active: Vec<ActiveRequest> = Vec::new();
        loop {
            let t = tag(PHASE_CTRL, 0, self.ctrl_seq);
            let Some(env) = self.recv_or_shutdown(t, rx)? else {
                return Ok(());
            };
            self.ctrl_seq = self.ctrl_seq.wrapping_add(1);
            let Some((&op, body)) = env.payload.split_first() else {
                anyhow::bail!("node {}: empty control message", self.node);
            };
            match op {
                OP_SHUTDOWN => return Ok(()),
                OP_HEARTBEAT => {} // liveness beacon; the seq bump above replays it
                OP_ADMIT => {
                    anyhow::ensure!(body.len() > 2, "short admit message");
                    let seq = u16::from_le_bytes(body[0..2].try_into().expect("2-byte slice"));
                    let req = Request::decode(&body[2..])
                        .with_context(|| format!("node {}: decoding admission", self.node))?;
                    let a = self.admit(req, seq, None, None, None)?;
                    active.push(a);
                }
                OP_CANCEL => {
                    anyhow::ensure!(body.len() == 2, "short cancel message");
                    let seq = u16::from_le_bytes(body[0..2].try_into().expect("2-byte slice"));
                    active.retain(|a| a.seq != seq);
                }
                OP_STEP => {
                    anyhow::ensure!(body.len() == 2, "short step message");
                    let seq = u16::from_le_bytes(body[0..2].try_into().expect("2-byte slice"));
                    let _sp = obs::span("sched.iteration").arg("active", 1);
                    let Some(a) = active.iter_mut().find(|a| a.seq == seq) else {
                        anyhow::bail!(
                            "node {}: step for unknown request seq {seq}",
                            self.node
                        );
                    };
                    self.step(a)?;
                    if a.finish.is_some() {
                        active.retain(|a| a.finish.is_none());
                    }
                }
                OP_BATCH => {
                    // One mixed scheduler iteration: the packed decode
                    // participant list (u16 count + u16 seq each), plus
                    // an optional trailing prefill descriptor (u16 seq,
                    // u16 chunk, u16 real rows). Participants must
                    // mirror this node's active order — minus the
                    // prefill row — exactly (admissions/cancels
                    // replicate in order, so they do unless the planes
                    // desynced).
                    anyhow::ensure!(body.len() >= 2, "short batch message");
                    let nr =
                        u16::from_le_bytes(body[0..2].try_into().expect("2-byte slice")) as usize;
                    let pre = match body.len() {
                        n if n == 2 + 2 * nr => None,
                        n if n == 2 + 2 * nr + 6 => {
                            let o = 2 + 2 * nr;
                            let two = |a: usize| -> u16 {
                                u16::from_le_bytes(
                                    body[a..a + 2].try_into().expect("2-byte slice"),
                                )
                            };
                            Some((two(o), two(o + 2) as usize, two(o + 4) as usize))
                        }
                        _ => anyhow::bail!("batch message length mismatch"),
                    };
                    let seqs: Vec<u16> = (0..nr)
                        .map(|r| {
                            let b = body[2 + 2 * r..4 + 2 * r].try_into().expect("2-byte slice");
                            u16::from_le_bytes(b)
                        })
                        .collect();
                    let pi = match pre {
                        None => None,
                        Some((pseq, chunk, real)) => {
                            anyhow::ensure!(
                                self.rt.manifest.prefill_chunks().contains(&chunk)
                                    && (1..=chunk).contains(&real),
                                "node {}: malformed prefill descriptor \
                                 (chunk {chunk}, real {real})",
                                self.node
                            );
                            let pi = active
                                .iter()
                                .position(|a| a.seq == pseq)
                                .with_context(|| {
                                    format!(
                                        "node {}: prefill chunk for unknown request seq {pseq}",
                                        self.node
                                    )
                                })?;
                            Some(pi)
                        }
                    };
                    let expect: Vec<u16> = active
                        .iter()
                        .enumerate()
                        .filter(|(i, _)| Some(*i) != pi)
                        .map(|(_, a)| a.seq)
                        .collect();
                    anyhow::ensure!(
                        seqs == expect,
                        "node {}: batch participants desynced from the admission order",
                        self.node
                    );
                    let _sp =
                        obs::span("sched.iteration").arg("active", active.len() as u64);
                    if let (Some(pi), Some((_, chunk, real))) = (pi, pre) {
                        self.prefill_chunk_step(&mut active[pi], chunk, real)?;
                    }
                    self.batch_iteration(&mut active, pi)?;
                    active.retain(|a| a.finish.is_none());
                }
                OP_TRACE_FLUSH => self.ship_trace(),
                other => anyhow::bail!("node {}: unknown ctrl opcode {other}", self.node),
            }
        }
    }

    /// Centralized worker: stateless per iteration. Each scatter carries
    /// (layer, moe_in, slot assignments) under a global sequence number;
    /// the worker computes its partial and replies on the same number.
    /// An empty scatter is the shutdown marker.
    fn follow_central_worker(&mut self, rx: Option<&Receiver<Cmd>>) -> Result<()> {
        let d = self.rt.manifest.d_embed;
        loop {
            let t = tag(PHASE_SCATTER, 0, self.wseq);
            let Some(env) = self.recv_or_shutdown(t, rx)? else {
                return Ok(());
            };
            if env.payload.is_empty() {
                return Ok(());
            }
            if env.payload.len() == 1 && env.payload[0] == SCATTER_HEARTBEAT {
                // Leader liveness beacon: consume its sequence number
                // and keep waiting for real work.
                self.wseq = self.wseq.wrapping_add(1);
                continue;
            }
            anyhow::ensure!(
                env.payload.len() >= 8 + d * 4,
                "node {}: short scatter payload",
                self.node
            );
            let layer =
                u32::from_le_bytes(env.payload[0..4].try_into().expect("4-byte slice")) as usize;
            let rows_field = u32::from_le_bytes(env.payload[4..8].try_into().expect("4-byte slice"));
            // The high bit marks a chunked-prefill scatter: the row
            // count is then a `dev_p{T}_*` chunk size (validated against
            // the compiled family, not the decode-bucket bound).
            let is_prefill = rows_field & SCATTER_PREFILL_ROWS != 0;
            let rows = (rows_field & !SCATTER_PREFILL_ROWS) as usize;
            let rows_ok = if is_prefill {
                self.rt.manifest.prefill_chunks().contains(&rows)
            } else {
                (1..=64).contains(&rows)
            };
            anyhow::ensure!(
                rows_ok && env.payload.len() >= 8 + rows * d * 4,
                "node {}: malformed scatter payload (rows {rows})",
                self.node
            );
            let moe_in = bytes_to_f32s(&env.payload[8..8 + rows * d * 4]);
            let rest = &env.payload[8 + rows * d * 4..];
            anyhow::ensure!(
                !rest.is_empty() && rest.len() % (8 * rows) == 0,
                "node {}: malformed slot assignment",
                self.node
            );
            let ns = rest.len() / (8 * rows); // slot count rides on the wire
            let total = rows * ns;
            let mut idx = vec![0i32; total];
            let mut w = vec![0f32; total];
            for s in 0..total {
                let o = s * 8;
                idx[s] = i32::from_le_bytes(rest[o..o + 4].try_into().expect("4-byte slice"));
                w[s] = f32::from_le_bytes(rest[o + 4..o + 8].try_into().expect("4-byte slice"));
            }
            // rows == 1 is the serial iteration; rows > 1 is one
            // continuously-batched iteration; a flagged scatter is one
            // prefill chunk — either way this node's experts run for
            // every row in ONE dispatch and reply with the [rows, D]
            // partial in ONE message.
            let sp = obs::span("experts.dispatch").arg("layer", layer as u64);
            let partial = if is_prefill {
                self.rt.node_experts_prefill(&self.experts, layer, rows, &moe_in, &idx, &w)?
            } else if rows == 1 {
                let idx: Vec<usize> = idx.iter().map(|&i| i as usize).collect();
                self.rt.node_experts_direct(&self.experts, layer, &moe_in, &idx, &w)?
            } else {
                self.rt
                    .node_experts_batched(&self.experts, layer, rows, &moe_in, &idx, &w)?
            };
            drop(sp);
            self.ep
                .send(0, tag(PHASE_GATHER, 0, self.wseq), f32s_to_bytes(&partial))?;
            self.wseq = self.wseq.wrapping_add(1);
        }
    }

    // ---------------- one engine iteration ----------

    /// Phase A of ANY iteration, replicated on every node: decide the
    /// request's next input token — consume the next prompt token, take
    /// the token the device sampler drew at the end of the previous
    /// forward pass, or (host-sampler path) sample from the downloaded
    /// logits (the token is recorded, streamed, and checked against the
    /// stop set here). Returns `None` when the request finished instead
    /// of needing a forward pass (stop token sampled, or context window
    /// exhausted), `Some((token, is_prefill))` otherwise.
    ///
    /// Load-bearing for cross-node determinism: the serial (`OP_STEP`)
    /// and batched (`OP_BATCH`) iterations share this exact sequence,
    /// and sampling is stateless — the draw for the token at position
    /// `a.pos` is `threefry(seed, a.pos)` on both the host and device
    /// paths, so tokens can never diverge between nodes, paths, or
    /// bucket shifts.
    fn decide_token(&self, a: &mut ActiveRequest) -> Option<(u32, bool)> {
        if a.pos >= self.rt.manifest.max_seq {
            a.finish = Some(FinishReason::Length);
            return None;
        }
        if a.pos < a.req.prompt.len() {
            return Some((a.req.prompt[a.pos], true));
        }
        let (t, lp, stop_hit) = match a.pending_sample.take() {
            // The previous forward's device sampler already drew at
            // counter `a.pos` (its forward position + 1) and checked
            // the stop set on device.
            Some(s) => (s.token, s.logprob, s.stop_hit),
            None => {
                let (t, lp) = a.req.sampling.sampler.sample_lp_at(
                    &a.last_logits,
                    a.req.sampling.seed,
                    a.pos as u32,
                );
                (t, lp, a.req.sampling.stop.contains(&t))
            }
        };
        a.generated.push(t);
        emit_token(a, t, lp);
        if stop_hit {
            // The stop token is recorded but its forward pass is
            // skipped.
            a.finish = Some(FinishReason::Stop);
            return None;
        }
        Some((t, false))
    }

    /// Advance `a` by one serial iteration: decide its token
    /// ([`NodeWorker::decide_token`]) and run its batch-1 forward pass.
    /// Sets `a.finish` when the request completed.
    fn step(&mut self, a: &mut ActiveRequest) -> Result<()> {
        match self.decide_token(a) {
            None => Ok(()),
            Some((tok, is_prefill)) => self.advance_one(a, tok, is_prefill),
        }
    }

    /// Run one request's batch-1 forward pass for `tok` and book its
    /// metrics/position (the tail of [`NodeWorker::step`], shared with
    /// the batched iteration's lone-runner floor).
    fn advance_one(&mut self, a: &mut ActiveRequest, tok: u32, is_prefill: bool) -> Result<()> {
        let on_device = matches!(a.state, DecodeState::Dev(_));
        let b = match (self.cfg.topology, on_device) {
            (Topology::Decentralized, true) => self.forward_decentralized_dev(a, tok)?,
            (Topology::Decentralized, false) => self.forward_decentralized_host(a, tok)?,
            (Topology::Centralized, true) => self.forward_central_leader_dev(a, tok)?,
            (Topology::Centralized, false) => self.forward_central_leader_host(a, tok)?,
        };

        if is_prefill {
            a.metrics.prefill.push(b);
        } else {
            a.metrics.decode.push(b);
        }
        a.pos += 1;
        a.step += 1;
        if a.generated.len() >= a.req.sampling.max_new_tokens {
            a.finish = Some(FinishReason::Length);
        }
        Ok(())
    }

    // ---------------- the chunked-prefill iteration ----------

    /// Run ONE prefill chunk for `a`: `real` prompt tokens at
    /// `a.pos..a.pos+real`, evaluated through a `[chunk, D]` forward
    /// pass ([`PrefillRun`]) that shares each layer's dispatch train
    /// across all rows — the prompt phase pays ~1/chunk of the serial
    /// per-token `exec_calls`, and the data plane carries ONE
    /// `[chunk, D]` payload per exchange (all-reduce or scatter/gather,
    /// the latter flagged with [`SCATTER_PREFILL_ROWS`]). Replicated on
    /// every decentralized node from the `OP_BATCH` prefill descriptor;
    /// centralized workers are driven by the flagged scatter alone.
    ///
    /// No logits and no sampling here: the last prompt token never
    /// enters a chunk (see [`NodeWorker::select_prefill`]), so the only
    /// state a chunk leaves behind is K/V appends — bit-identical to
    /// `real` serial steps.
    fn prefill_chunk_step(
        &mut self,
        a: &mut ActiveRequest,
        chunk: usize,
        real: usize,
    ) -> Result<()> {
        let n_layers = self.rt.manifest.n_layers;
        let ns = self.plan_ns();
        let mut b = TokenBreakdown::default();
        self.rt.take_transfer_stats();
        self.ep.take_stats();
        anyhow::ensure!(
            real >= 1 && a.pos + real < a.req.prompt.len(),
            "prefill chunk overruns the prompt (pos {}, real {real}, prompt {})",
            a.pos,
            a.req.prompt.len()
        );
        let toks: Vec<u32> = a.req.prompt[a.pos..a.pos + real].to_vec();
        let (seq, step0, pos) = (a.seq, a.step, a.pos);
        let DecodeState::Dev(state) = &mut a.state else {
            anyhow::bail!("chunked prefill on host state")
        };

        let t_embed = Instant::now();
        let mut run = PrefillRun::begin(&self.rt, chunk, state, &toks, pos)?;
        b.misc_ns += t_embed.elapsed().as_nanos() as u64;

        for l in 0..n_layers {
            let t_misc = Instant::now();
            let sp = obs::span("attn.router").arg("layer", l as u64);
            let draws = run.attn_router(&self.rt, l)?;
            let mut plans = Vec::with_capacity(draws.len());
            for (top_w, top_i) in draws {
                plans.push(
                    self.planner.plan_layer(&RouterDraw { selected: top_i, weights: top_w }),
                );
            }
            drop(sp);
            b.misc_ns += t_misc.elapsed().as_nanos() as u64;

            match self.cfg.topology {
                Topology::Decentralized => {
                    let t_moe = Instant::now();
                    let sp = obs::span("experts.dispatch").arg("layer", l as u64);
                    let (idx, w) = self.batch_slots(&plans, self.node, chunk, ns);
                    let partial = run.node_experts(&self.rt, &self.experts, l, &idx, &w)?;
                    drop(sp);
                    b.moe_ns += t_moe.elapsed().as_nanos() as u64;

                    if self.ep.n_nodes() == 1 {
                        let t_sum = Instant::now();
                        run.finish_layer_device(&self.rt, &partial)?;
                        b.misc_ns += t_sum.elapsed().as_nanos() as u64;
                    } else {
                        // ONE [chunk, D] all-reduce for the whole chunk.
                        let t_comm = Instant::now();
                        let mine = self.rt.download_f32(&partial)?;
                        let summed = self.all_reduce(&mine, seq, l as u32, step0)?;
                        b.comm_ns += t_comm.elapsed().as_nanos() as u64;

                        let t_sum = Instant::now();
                        run.finish_layer_host(&self.rt, &summed)?;
                        b.misc_ns += t_sum.elapsed().as_nanos() as u64;
                    }
                }
                Topology::Centralized => {
                    let w_iter = self.next_wseq();
                    let t_comm = Instant::now();
                    if let Some(w_iter) = w_iter {
                        let moe_in = run.moe_in_host(&self.rt)?; // [chunk, D] scatter
                        self.scatter_rows(&plans, &moe_in, chunk, true, l as u32, w_iter)?;
                    }
                    b.comm_ns += t_comm.elapsed().as_nanos() as u64;

                    let t_moe = Instant::now();
                    let sp = obs::span("experts.dispatch").arg("layer", l as u64);
                    let (idx, w) = self.batch_slots(&plans, 0, chunk, ns);
                    let partial = run.node_experts(&self.rt, &self.experts, l, &idx, &w)?;
                    drop(sp);
                    b.moe_ns += t_moe.elapsed().as_nanos() as u64;

                    match w_iter {
                        None => {
                            let t_sum = Instant::now();
                            run.finish_layer_device(&self.rt, &partial)?;
                            b.misc_ns += t_sum.elapsed().as_nanos() as u64;
                        }
                        Some(w_iter) => {
                            let t_gather = Instant::now();
                            let mine = self.rt.download_f32(&partial)?;
                            let sum = self.gather_partials(mine, w_iter, l as u32)?;
                            b.comm_ns += t_gather.elapsed().as_nanos() as u64;

                            let t_sum = Instant::now();
                            run.finish_layer_host(&self.rt, &sum)?;
                            b.misc_ns += t_sum.elapsed().as_nanos() as u64;
                        }
                    }
                }
            }
        }
        drop(run); // release the DeviceState borrow before bookkeeping
        note_transfers(&mut b, &self.rt);
        note_wire(&mut b, self.ep.take_stats());

        // Book a 1/real share per prompt position the chunk advanced:
        // the prefill phase's per-token statistics (exec_calls_per_token
        // above all) stay comparable to serial steps, and `batch_rows`
        // records how many positions shared the dispatches.
        let nd = real as u64;
        let share = TokenBreakdown {
            moe_ns: b.moe_ns / nd,
            comm_ns: b.comm_ns / nd,
            misc_ns: b.misc_ns / nd,
            h2d_ns: b.h2d_ns / nd,
            d2h_ns: b.d2h_ns / nd,
            h2d_bytes: b.h2d_bytes / nd,
            d2h_bytes: b.d2h_bytes / nd,
            net_msgs: b.net_msgs / nd,
            net_bytes: b.net_bytes / nd,
            batch_rows: real as u32,
            exec_calls: b.exec_calls / nd,
        };
        for _ in 0..real {
            a.metrics.prefill.push(share);
        }
        a.pos += real;
        a.step += 1;
        Ok(())
    }

    // ---------------- the continuously-batched iteration ----------

    /// One continuous-batching iteration over the packed participants
    /// (the whole active list, in schedule order): replicated verbatim
    /// on every decentralized node from the `OP_BATCH` participant
    /// list.
    ///
    /// Phase A decides each request's token — consume the next prompt
    /// token, or sample from its own logits with its own replicated
    /// sampler stream. A sampled stop token (or an exhausted context
    /// window) finishes the request WITHOUT a forward pass, exactly as
    /// on the serial path. Phase B packs the remaining runners into the
    /// largest fitting bucket and runs ONE shared forward (chunking
    /// only when the active count exceeds the largest compiled bucket;
    /// a lone runner takes the batch-1 path — the bucket floor).
    ///
    /// `skip` names the row a prefill chunk already advanced this
    /// iteration (mixed iterations); it neither decides a token nor
    /// joins the decode batch.
    fn batch_iteration(&mut self, active: &mut [ActiveRequest], skip: Option<usize>) -> Result<()> {
        let mut runners: Vec<usize> = Vec::new();
        let mut tokens: Vec<u32> = Vec::new();
        let mut prefill: Vec<bool> = Vec::new();
        for (i, a) in active.iter_mut().enumerate() {
            if Some(i) == skip || a.finish.is_some() {
                continue;
            }
            if let Some((tok, is_prefill)) = self.decide_token(a) {
                runners.push(i);
                tokens.push(tok);
                prefill.push(is_prefill);
            }
        }
        // Pre-batching artifacts degrade to size-1 groups (the batch-1
        // path below) — mixed iterations still chunk the prompt.
        let max_bucket = self.rt.manifest.batch_buckets().last().copied().unwrap_or(1);
        let mut c = 0;
        while c < runners.len() {
            let n = (runners.len() - c).min(max_bucket);
            if n == 1 {
                let i = runners[c];
                let (tok, pref) = (tokens[c], prefill[c]);
                self.advance_one(&mut active[i], tok, pref)?;
            } else {
                let chunk: Vec<usize> = runners[c..c + n].to_vec();
                let toks: Vec<u32> = tokens[c..c + n].to_vec();
                let pref: Vec<bool> = prefill[c..c + n].to_vec();
                self.forward_batch(active, &chunk, &toks, &pref)?;
            }
            c += n;
        }
        Ok(())
    }

    /// ONE shared forward pass for the runner rows (`rows` indexes into
    /// `active`, ascending; 2 ≤ rows ≤ bucket). The runners'
    /// [`DeviceState`]s become the batch rows of a [`BatchedRun`]; per
    /// layer, every node executes the same per-row plans in the same
    /// row order, and the data plane carries ONE `[B, ...]` payload per
    /// exchange (tagged by the first row's identity). The shared
    /// iteration cost is attributed evenly: each row books a 1/B share
    /// of the breakdown with `batch_rows = B`.
    fn forward_batch(
        &mut self,
        active: &mut [ActiveRequest],
        rows: &[usize],
        toks: &[u32],
        pref: &[bool],
    ) -> Result<()> {
        let n = rows.len();
        let bucket = self
            .rt
            .bucket_for(n)
            .with_context(|| format!("no artifact bucket fits {n} rows"))?;
        let n_layers = self.rt.manifest.n_layers;
        let vocab = self.rt.manifest.vocab;
        let ns = self.plan_ns();
        let mut b = TokenBreakdown::default();
        self.rt.take_transfer_stats();
        self.ep.take_stats();

        // The shared payloads ride under the first row's identity —
        // replicated state, so identical on every node and unique per
        // iteration (that row's step advances each time).
        let seq0 = active[rows[0]].seq;
        let step0 = active[rows[0]].step;
        let positions: Vec<usize> = rows.iter().map(|&i| active[i].pos).collect();

        // Whole-batch sampler decision, replicated on every node: the
        // chunk samples on device only when EVERY row is eligible — one
        // incompatible request (k or stop set beyond the artifact
        // operand widths) drops the whole chunk back to the [B, V]
        // logits download; its rows still produce identical tokens
        // because the host sampler draws the same stateless counters.
        let dev_sampling = rows.iter().all(|&i| self.use_device_sampler(&active[i]));
        let wills: Vec<bool> = rows.iter().map(|&i| self.will_sample(&active[i])).collect();
        let dev_inputs: Option<Vec<DeviceSampleInputs>> =
            (dev_sampling && wills.iter().any(|&w| w)).then(|| {
                let max_stop = self.rt.manifest.sampler_max_stop;
                rows.iter().map(|&i| active[i].req.sampling.device_inputs(max_stop)).collect()
            });

        // Split borrow: the runners' DeviceStates become the batch rows;
        // everything else on the requests is touched only after the
        // forward completes.
        let mut in_batch = vec![false; active.len()];
        for &i in rows {
            in_batch[i] = true;
        }
        let mut states: Vec<&mut DeviceState> = Vec::with_capacity(n);
        for (i, a) in active.iter_mut().enumerate() {
            if in_batch[i] {
                match &mut a.state {
                    DecodeState::Dev(d) => states.push(d),
                    DecodeState::Host { .. } => {
                        anyhow::bail!("batched forward on host state")
                    }
                }
            }
        }

        let t_embed = Instant::now();
        let mut run = BatchedRun::begin(&self.rt, bucket, states, toks, &positions)?;
        b.misc_ns += t_embed.elapsed().as_nanos() as u64;

        for l in 0..n_layers {
            let t_misc = Instant::now();
            let sp = obs::span("attn.router").arg("layer", l as u64);
            let draws = run.attn_router(&self.rt, l)?;
            let mut plans = Vec::with_capacity(draws.len());
            for (top_w, top_i) in draws {
                plans.push(
                    self.planner.plan_layer(&RouterDraw { selected: top_i, weights: top_w }),
                );
            }
            drop(sp);
            b.misc_ns += t_misc.elapsed().as_nanos() as u64;

            match self.cfg.topology {
                Topology::Decentralized => {
                    let t_moe = Instant::now();
                    let sp = obs::span("experts.dispatch").arg("layer", l as u64);
                    let (idx, w) = self.batch_slots(&plans, self.node, bucket, ns);
                    let partial = run.node_experts(&self.rt, &self.experts, l, &idx, &w)?;
                    drop(sp);
                    b.moe_ns += t_moe.elapsed().as_nanos() as u64;

                    if self.ep.n_nodes() == 1 {
                        let t_sum = Instant::now();
                        run.finish_layer_device(&self.rt, &partial)?;
                        b.misc_ns += t_sum.elapsed().as_nanos() as u64;
                    } else {
                        // ONE [B, D] all-reduce for the whole batch.
                        let t_comm = Instant::now();
                        let mine = self.rt.download_f32(&partial)?;
                        let summed = self.all_reduce(&mine, seq0, l as u32, step0)?;
                        b.comm_ns += t_comm.elapsed().as_nanos() as u64;

                        let t_sum = Instant::now();
                        run.finish_layer_host(&self.rt, &summed)?;
                        b.misc_ns += t_sum.elapsed().as_nanos() as u64;
                    }
                }
                Topology::Centralized => {
                    let w_iter = self.next_wseq();
                    let t_comm = Instant::now();
                    if let Some(w_iter) = w_iter {
                        let moe_in = run.moe_in_host(&self.rt)?; // [B, D] scatter payload
                        self.scatter_rows(&plans, &moe_in, bucket, false, l as u32, w_iter)?;
                    }
                    b.comm_ns += t_comm.elapsed().as_nanos() as u64;

                    let t_moe = Instant::now();
                    let sp = obs::span("experts.dispatch").arg("layer", l as u64);
                    let (idx, w) = self.batch_slots(&plans, 0, bucket, ns);
                    let partial = run.node_experts(&self.rt, &self.experts, l, &idx, &w)?;
                    drop(sp);
                    b.moe_ns += t_moe.elapsed().as_nanos() as u64;

                    match w_iter {
                        None => {
                            let t_sum = Instant::now();
                            run.finish_layer_device(&self.rt, &partial)?;
                            b.misc_ns += t_sum.elapsed().as_nanos() as u64;
                        }
                        Some(w_iter) => {
                            let t_gather = Instant::now();
                            let mine = self.rt.download_f32(&partial)?;
                            let sum = self.gather_partials(mine, w_iter, l as u32)?;
                            b.comm_ns += t_gather.elapsed().as_nanos() as u64;

                            let t_sum = Instant::now();
                            run.finish_layer_host(&self.rt, &sum)?;
                            b.misc_ns += t_sum.elapsed().as_nanos() as u64;
                        }
                    }
                }
            }
        }

        // ONE download closes the iteration: the [B, 2] packed samples
        // (+ [B] stop mask) on the device-sampler path, the full [B, V]
        // logits on the host-sampler reference path. A chunk whose rows
        // are ALL mid-prefill on the device-sampler path skips lm_head
        // and the download entirely.
        let t_head = Instant::now();
        let head_sp =
            obs::span(if dev_inputs.is_some() { "sample.device" } else { "logits.d2h" });
        let mut all_logits = Vec::new();
        let mut samples: Vec<DeviceSample> = Vec::new();
        if let Some(inputs) = &dev_inputs {
            samples = run.sample_on_device(&self.rt, inputs)?;
        } else if !dev_sampling {
            run.logits_into(&self.rt, &mut all_logits)?;
        }
        drop(head_sp);
        b.misc_ns += t_head.elapsed().as_nanos() as u64;
        drop(run); // release the DeviceState borrows before bookkeeping
        note_transfers(&mut b, &self.rt);
        note_wire(&mut b, self.ep.take_stats());

        // Attribute the shared iteration evenly: a 1/B share per row
        // (integer division; the remainder ns/bytes are dropped).
        let nd = n as u64;
        let share = TokenBreakdown {
            moe_ns: b.moe_ns / nd,
            comm_ns: b.comm_ns / nd,
            misc_ns: b.misc_ns / nd,
            h2d_ns: b.h2d_ns / nd,
            d2h_ns: b.d2h_ns / nd,
            h2d_bytes: b.h2d_bytes / nd,
            d2h_bytes: b.d2h_bytes / nd,
            net_msgs: b.net_msgs / nd,
            net_bytes: b.net_bytes / nd,
            batch_rows: n as u32,
            exec_calls: b.exec_calls / nd,
        };
        for (r, &i) in rows.iter().enumerate() {
            let a = &mut active[i];
            a.last_logits.clear();
            if dev_sampling {
                a.pending_sample = wills[r].then(|| samples[r]);
            } else {
                a.pending_sample = None;
                a.last_logits.extend_from_slice(&all_logits[r * vocab..(r + 1) * vocab]);
            }
            if pref[r] {
                a.metrics.prefill.push(share);
            } else {
                a.metrics.decode.push(share);
            }
            a.pos += 1;
            a.step += 1;
            if a.generated.len() >= a.req.sampling.max_new_tokens {
                a.finish = Some(FinishReason::Length);
            }
        }
        Ok(())
    }

    /// Row-major `[bucket, ns]` slot arrays for `node` from the per-row
    /// plans (weight 0 on padding slots and on padding rows beyond the
    /// planned ones).
    fn batch_slots(
        &self,
        plans: &[crate::moe::balance::LayerPlan],
        node: usize,
        bucket: usize,
        ns: usize,
    ) -> (Vec<i32>, Vec<f32>) {
        let mut idx = vec![0i32; bucket * ns];
        let mut w = vec![0f32; bucket * ns];
        for (r, plan) in plans.iter().enumerate() {
            let (ri, rw) = slots_from_index(&plan.per_node[node], &self.peer_index[node], ns);
            for s in 0..ns {
                idx[r * ns + s] = ri[s] as i32;
                w[r * ns + s] = rw[s];
            }
        }
        (idx, w)
    }

    // ---------------- decentralized (P-L_R-D wire protocol) ----------

    fn forward_decentralized_host(
        &mut self,
        a: &mut ActiveRequest,
        tok: u32,
    ) -> Result<TokenBreakdown> {
        let n_layers = self.rt.manifest.n_layers;
        let mut b = TokenBreakdown::default();
        self.rt.take_transfer_stats();
        self.ep.take_stats();
        let t_embed = Instant::now();
        let mut x = self.rt.embed(tok)?;
        b.misc_ns += t_embed.elapsed().as_nanos() as u64;

        let DecodeState::Host { kc, vc } = &mut a.state else {
            anyhow::bail!("host forward on device state")
        };
        for l in 0..n_layers {
            let t_misc = Instant::now();
            let sp = obs::span("attn.router").arg("layer", l as u64);
            let ar = self.rt.attn_router(l, &x, &kc[l], &vc[l], a.pos)?;
            kc[l] = ar.k_cache;
            vc[l] = ar.v_cache;
            let draw = RouterDraw {
                selected: ar.top_i.clone(),
                weights: ar.top_w.clone(),
            };
            let plan = self.planner.plan_layer(&draw);
            drop(sp);
            b.misc_ns += t_misc.elapsed().as_nanos() as u64;

            // Local expert slots.
            let t_moe = Instant::now();
            let sp = obs::span("experts.dispatch").arg("layer", l as u64);
            let (idx, w) = self.slots_for(&plan.per_node[self.node]);
            let partial =
                self.rt.node_experts_direct(&self.experts, l, &ar.moe_in, &idx, &w)?;
            drop(sp);
            b.moe_ns += t_moe.elapsed().as_nanos() as u64;

            // All-reduce (the envoy exchange of Fig. 7), demultiplexed
            // per request.
            let t_comm = Instant::now();
            let summed = self.all_reduce(&partial, a.seq, l as u32, a.step)?;
            b.comm_ns += t_comm.elapsed().as_nanos() as u64;

            let t_sum = Instant::now();
            for (xi, (hi, ci)) in x.iter_mut().zip(ar.h.iter().zip(&summed)) {
                *xi = hi + ci;
            }
            b.misc_ns += t_sum.elapsed().as_nanos() as u64;
        }
        let t_head = Instant::now();
        let head_sp = obs::span("lm_head");
        a.last_logits = self.rt.lm_head(&x)?;
        drop(head_sp);
        b.misc_ns += t_head.elapsed().as_nanos() as u64;
        note_transfers(&mut b, &self.rt);
        note_wire(&mut b, self.ep.take_stats());
        Ok(b)
    }

    /// Decentralized forward on the device-resident path: identical wire
    /// protocol (P-L_R-D) and identical math, but K/V caches and the
    /// x/h/moe_in activations never leave the device — the only host
    /// crossings per layer are the router's top-k and the all-reduce
    /// payload (see `runtime::device`). Per-bucket times here attribute
    /// async PJRT work to whichever call blocks first (see the
    /// `TokenBreakdown` caveat); totals stay comparable to the host
    /// path.
    fn forward_decentralized_dev(
        &mut self,
        a: &mut ActiveRequest,
        tok: u32,
    ) -> Result<TokenBreakdown> {
        let n_layers = self.rt.manifest.n_layers;
        let mut b = TokenBreakdown::default();
        let sample_dev = self.use_device_sampler(a);
        let will_sample = self.will_sample(a);
        self.rt.take_transfer_stats();
        self.ep.take_stats();
        let DecodeState::Dev(state) = &mut a.state else {
            anyhow::bail!("device forward on host state")
        };
        let t_embed = Instant::now();
        state.begin_token(&self.rt, tok)?;
        b.misc_ns += t_embed.elapsed().as_nanos() as u64;

        for l in 0..n_layers {
            let t_misc = Instant::now();
            let sp = obs::span("attn.router").arg("layer", l as u64);
            let (top_w, top_i) = state.attn_router(&self.rt, l, a.pos)?;
            let draw = RouterDraw { selected: top_i, weights: top_w };
            let plan = self.planner.plan_layer(&draw);
            drop(sp);
            b.misc_ns += t_misc.elapsed().as_nanos() as u64;

            let t_moe = Instant::now();
            let sp = obs::span("experts.dispatch").arg("layer", l as u64);
            let (idx, w) = self.slots_for(&plan.per_node[self.node]);
            let partial = state.node_experts(&self.rt, &self.experts, l, &idx, &w)?;
            drop(sp);
            b.moe_ns += t_moe.elapsed().as_nanos() as u64;

            if self.ep.n_nodes() == 1 {
                // Single node: the local partial IS the sum — it never
                // leaves the device.
                let t_sum = Instant::now();
                state.finish_layer_device(&self.rt, &partial)?;
                b.misc_ns += t_sum.elapsed().as_nanos() as u64;
            } else {
                // The partial must hit the wire: this download (and the
                // summed upload) are protocol traffic.
                let t_comm = Instant::now();
                let mine = self.rt.download_f32(&partial)?;
                let summed = self.all_reduce(&mine, a.seq, l as u32, a.step)?;
                b.comm_ns += t_comm.elapsed().as_nanos() as u64;

                let t_sum = Instant::now();
                state.finish_layer_host(&self.rt, &summed)?;
                b.misc_ns += t_sum.elapsed().as_nanos() as u64;
            }
        }
        let t_head = Instant::now();
        let head_sp = obs::span(if sample_dev { "sample.device" } else { "logits.d2h" });
        if sample_dev {
            // The d2h collapse: 8 bytes of (token, logprob) — plus a
            // 4-byte stop mask — instead of the [1, V] logits. Pure
            // prefill iterations skip lm_head + sampler entirely.
            a.pending_sample = if will_sample {
                let inp = a.req.sampling.device_inputs(self.rt.manifest.sampler_max_stop);
                Some(state.sample_on_device(&self.rt, &inp, a.pos)?)
            } else {
                None
            };
        } else {
            state.logits_into(&self.rt, &mut a.last_logits)?;
        }
        drop(head_sp);
        b.misc_ns += t_head.elapsed().as_nanos() as u64;
        note_transfers(&mut b, &self.rt);
        note_wire(&mut b, self.ep.take_stats());
        Ok(b)
    }

    /// Exchange partials with every peer and sum in node order (bitwise
    /// deterministic across nodes).
    fn all_reduce(
        &mut self,
        partial: &[f32],
        seq: u16,
        layer: u32,
        step: u32,
    ) -> Result<Vec<f32>> {
        if self.ep.n_nodes() == 1 {
            return Ok(partial.to_vec());
        }
        let _sp = obs::span("allreduce.wait").arg("layer", layer as u64);
        let t = req_tag(PHASE_PARTIAL, seq, layer, step);
        self.ep.broadcast(t, &f32s_to_bytes(partial))?;
        let envs = self
            .ep
            .gather(t, self.cfg.recv_timeout)
            .with_context(|| {
                format!("node {}: all-reduce, request seq {seq}, layer {layer}", self.node)
            })?;
        self.note_followers_alive();
        let mut parts: Vec<(usize, Vec<f32>)> =
            envs.into_iter().map(|e| (e.from, bytes_to_f32s(&e.payload))).collect();
        parts.push((self.node, partial.to_vec()));
        parts.sort_by_key(|(n, _)| *n);
        let d = partial.len();
        let mut acc = vec![0.0f32; d];
        for (_, p) in parts {
            for (a, v) in acc.iter_mut().zip(p) {
                *a += v;
            }
        }
        Ok(acc)
    }

    /// Slot count the artifacts expect under the active balancing mode:
    /// busy-full plans need all resident slots; router-aided and
    /// selected-only never exceed top_k, so they use the smaller fast
    /// artifact (§Perf).
    fn plan_ns(&self) -> usize {
        if self.cfg.balancing == Balancing::BusyFull {
            self.rt.manifest.num_slots
        } else {
            self.rt.manifest.fast_num_slots
        }
    }

    /// Map this node's `NodeWork` plan to the artifact's fixed slot
    /// arrays.
    fn slots_for(&self, work: &crate::moe::balance::NodeWork) -> (Vec<usize>, Vec<f32>) {
        slots_from_index(work, &self.peer_index[self.node], self.plan_ns())
    }

    // ---------------- centralized (Figs. 2–3 wire protocol) ----------

    fn forward_central_leader_host(
        &mut self,
        a: &mut ActiveRequest,
        tok: u32,
    ) -> Result<TokenBreakdown> {
        let n_layers = self.rt.manifest.n_layers;
        let mut b = TokenBreakdown::default();
        self.rt.take_transfer_stats();
        self.ep.take_stats();
        let t0 = Instant::now();
        let mut x = self.rt.embed(tok)?;
        b.misc_ns += t0.elapsed().as_nanos() as u64;

        let DecodeState::Host { kc, vc } = &mut a.state else {
            anyhow::bail!("host forward on device state")
        };
        for l in 0..n_layers {
            let t_misc = Instant::now();
            let sp = obs::span("attn.router").arg("layer", l as u64);
            let ar = self.rt.attn_router(l, &x, &kc[l], &vc[l], a.pos)?;
            kc[l] = ar.k_cache;
            vc[l] = ar.v_cache;
            let draw = RouterDraw {
                selected: ar.top_i.clone(),
                weights: ar.top_w.clone(),
            };
            let plan = self.planner.plan_layer(&draw);
            drop(sp);
            b.misc_ns += t_misc.elapsed().as_nanos() as u64;

            // Scatter: layer + moe_in + per-worker slot assignments
            // under one global sequence number.
            let w_iter = self.next_wseq();
            let t_comm = Instant::now();
            if let Some(w_iter) = w_iter {
                self.scatter_rows(std::slice::from_ref(&plan), &ar.moe_in, 1, false, l as u32, w_iter)?;
            }
            b.comm_ns += t_comm.elapsed().as_nanos() as u64;

            // Own experts.
            let t_moe = Instant::now();
            let sp = obs::span("experts.dispatch").arg("layer", l as u64);
            let (idx, w) = self.slots_for(&plan.per_node[0]);
            let mine =
                self.rt.node_experts_direct(&self.experts, l, &ar.moe_in, &idx, &w)?;
            drop(sp);
            b.moe_ns += t_moe.elapsed().as_nanos() as u64;

            // Gather partials.
            let t_gather = Instant::now();
            let sum = match w_iter {
                Some(w_iter) => self.gather_partials(mine, w_iter, l as u32)?,
                None => mine,
            };
            b.comm_ns += t_gather.elapsed().as_nanos() as u64;

            for (xi, (hi, ci)) in x.iter_mut().zip(ar.h.iter().zip(&sum)) {
                *xi = hi + ci;
            }
        }
        let t_head = Instant::now();
        let head_sp = obs::span("lm_head");
        a.last_logits = self.rt.lm_head(&x)?;
        drop(head_sp);
        b.misc_ns += t_head.elapsed().as_nanos() as u64;
        note_transfers(&mut b, &self.rt);
        note_wire(&mut b, self.ep.take_stats());
        Ok(b)
    }

    /// Centralized leader on the device-resident path: the Figs. 2–3
    /// wire protocol is unchanged (workers cannot tell the difference);
    /// the leader's caches/activations stay on device. The scatter's
    /// `moe_in` download and the gather-sum upload are protocol traffic.
    fn forward_central_leader_dev(
        &mut self,
        a: &mut ActiveRequest,
        tok: u32,
    ) -> Result<TokenBreakdown> {
        let n_layers = self.rt.manifest.n_layers;
        let mut b = TokenBreakdown::default();
        let sample_dev = self.use_device_sampler(a);
        let will_sample = self.will_sample(a);
        self.rt.take_transfer_stats();
        self.ep.take_stats();
        let DecodeState::Dev(state) = &mut a.state else {
            anyhow::bail!("device forward on host state")
        };
        let t0 = Instant::now();
        state.begin_token(&self.rt, tok)?;
        b.misc_ns += t0.elapsed().as_nanos() as u64;

        for l in 0..n_layers {
            let t_misc = Instant::now();
            let sp = obs::span("attn.router").arg("layer", l as u64);
            let (top_w, top_i) = state.attn_router(&self.rt, l, a.pos)?;
            let draw = RouterDraw { selected: top_i, weights: top_w };
            let plan = self.planner.plan_layer(&draw);
            drop(sp);
            b.misc_ns += t_misc.elapsed().as_nanos() as u64;

            let w_iter = self.next_wseq();
            let t_comm = Instant::now();
            if let Some(w_iter) = w_iter {
                let moe_in = state.moe_in_host(&self.rt)?; // scatter payload
                self.scatter_rows(std::slice::from_ref(&plan), &moe_in, 1, false, l as u32, w_iter)?;
            }
            b.comm_ns += t_comm.elapsed().as_nanos() as u64;

            let t_moe = Instant::now();
            let sp = obs::span("experts.dispatch").arg("layer", l as u64);
            let (idx, w) = self.slots_for(&plan.per_node[0]);
            let partial = state.node_experts(&self.rt, &self.experts, l, &idx, &w)?;
            drop(sp);
            b.moe_ns += t_moe.elapsed().as_nanos() as u64;

            match w_iter {
                None => {
                    let t_sum = Instant::now();
                    state.finish_layer_device(&self.rt, &partial)?;
                    b.misc_ns += t_sum.elapsed().as_nanos() as u64;
                }
                Some(w_iter) => {
                    let t_gather = Instant::now();
                    let mine = self.rt.download_f32(&partial)?;
                    let sum = self.gather_partials(mine, w_iter, l as u32)?;
                    b.comm_ns += t_gather.elapsed().as_nanos() as u64;

                    let t_sum = Instant::now();
                    state.finish_layer_host(&self.rt, &sum)?;
                    b.misc_ns += t_sum.elapsed().as_nanos() as u64;
                }
            }
        }
        let t_head = Instant::now();
        let head_sp = obs::span(if sample_dev { "sample.device" } else { "logits.d2h" });
        if sample_dev {
            // Same d2h collapse as the decentralized path; the workers
            // cannot tell the difference (the wire protocol carries no
            // logits either way).
            a.pending_sample = if will_sample {
                let inp = a.req.sampling.device_inputs(self.rt.manifest.sampler_max_stop);
                Some(state.sample_on_device(&self.rt, &inp, a.pos)?)
            } else {
                None
            };
        } else {
            state.logits_into(&self.rt, &mut a.last_logits)?;
        }
        drop(head_sp);
        b.misc_ns += t_head.elapsed().as_nanos() as u64;
        note_transfers(&mut b, &self.rt);
        note_wire(&mut b, self.ep.take_stats());
        Ok(b)
    }

    /// Allocate the next scatter/gather sequence number — `None` on a
    /// single-node cluster (no workers to talk to).
    fn next_wseq(&mut self) -> Option<u32> {
        if self.ep.n_nodes() == 1 {
            return None;
        }
        let w = self.wseq;
        self.wseq = self.wseq.wrapping_add(1);
        Some(w)
    }

    /// Leader-side scatter: layer + row count + `[rows, D]` moe_in +
    /// per-row per-worker slot assignments, all under one sequence
    /// number (shared by the host, device-resident, batched and
    /// chunked-prefill centralized loops — `rows == 1` is the serial
    /// case). `prefill` sets the [`SCATTER_PREFILL_ROWS`] high bit on
    /// the row count: `rows` is then a `dev_p{T}_*` chunk size and the
    /// worker dispatches the prefill expert role. Rows beyond
    /// `plans.len()` are bucket/chunk padding: zero weights, so the
    /// worker's padded partial rows are exact zeros.
    fn scatter_rows(
        &mut self,
        plans: &[crate::moe::balance::LayerPlan],
        moe_in: &[f32],
        rows: usize,
        prefill: bool,
        layer: u32,
        wseq: u32,
    ) -> Result<()> {
        let ns = self.plan_ns();
        debug_assert_eq!(moe_in.len(), rows * self.rt.manifest.d_embed);
        let rows_field = rows as u32 | if prefill { SCATTER_PREFILL_ROWS } else { 0 };
        let _sp = obs::span("scatter.send").arg("layer", layer as u64);
        for peer in 1..self.ep.n_nodes() {
            let mut payload = Vec::with_capacity(8 + moe_in.len() * 4 + rows * ns * 8);
            payload.extend_from_slice(&layer.to_le_bytes());
            payload.extend_from_slice(&rows_field.to_le_bytes());
            payload.extend_from_slice(&f32s_to_bytes(moe_in));
            // Per-row slot assignment appended: rows × ns × (i32, f32).
            for r in 0..rows {
                match plans.get(r) {
                    Some(plan) => {
                        let (idx, w) =
                            slots_from_index(&plan.per_node[peer], &self.peer_index[peer], ns);
                        for s in 0..ns {
                            payload.extend_from_slice(&(idx[s] as i32).to_le_bytes());
                            payload.extend_from_slice(&w[s].to_le_bytes());
                        }
                    }
                    None => {
                        for _ in 0..ns {
                            payload.extend_from_slice(&0i32.to_le_bytes());
                            payload.extend_from_slice(&0f32.to_le_bytes());
                        }
                    }
                }
            }
            self.ep.send(peer, tag(PHASE_SCATTER, 0, wseq), payload)?;
        }
        Ok(())
    }

    /// Leader-side gather: sum own partial with every worker's.
    fn gather_partials(&mut self, mine: Vec<f32>, wseq: u32, layer: u32) -> Result<Vec<f32>> {
        let _sp = obs::span("gather.wait").arg("layer", layer as u64);
        let envs = self
            .ep
            .gather(tag(PHASE_GATHER, 0, wseq), self.cfg.recv_timeout)
            .with_context(|| format!("leader: gathering partials, layer {layer}"))?;
        self.note_followers_alive();
        let mut sum = mine;
        for e in envs {
            for (a, v) in sum.iter_mut().zip(bytes_to_f32s(&e.payload)) {
                *a += v;
            }
        }
        Ok(sum)
    }
}

/// Map a `NodeWork` plan onto `ns` fixed slot arrays via a node's
/// global→local expert map (precomputed once per cluster in
/// `NodeWorker::new`); padding slots carry weight 0.
fn slots_from_index(
    work: &crate::moe::balance::NodeWork,
    index: &HashMap<usize, usize>,
    ns: usize,
) -> (Vec<usize>, Vec<f32>) {
    let mut idx = vec![0usize; ns];
    let mut w = vec![0f32; ns];
    for (s, run) in work.runs.iter().take(ns).enumerate() {
        let local = *index.get(&run.expert).expect("planner assigned non-resident expert");
        idx[s] = local;
        w[s] = if run.is_padding { 0.0 } else { run.weight };
    }
    (idx, w)
}

/// The fixed tag a follower's liveness beacons ride on (leader side
/// drains it per follower while idle).
pub fn beacon_tag(node: usize) -> u64 {
    tag(PHASE_FB, node as u32, 0)
}

/// A follower's periodic liveness beacon to node 0 — the symmetric twin
/// of the leader heartbeat (ROADMAP ">2-node follower liveness"
/// follow-up): before it, a follower that died mid-idle was only
/// noticed when the leader's NEXT gather timed out and named it; now
/// the idle leader bounds each follower's silence the same way
/// followers bound the leader's.
///
/// Beacons are sent from inside the follower's idle wait loops (every
/// poll tick once `period` has elapsed), so they flow exactly when the
/// follower is otherwise silent; while the cluster serves, the data
/// plane itself proves liveness and the leader refreshes its deadlines
/// on every gather instead.
pub struct Beacon {
    tag: u64,
    period: Duration,
    last: Option<Instant>,
}

impl Beacon {
    pub fn new(node: usize, period: Duration) -> Beacon {
        Beacon { tag: beacon_tag(node), period, last: None }
    }

    /// Send a beacon if one is due (immediately on the first call).
    /// Best effort: a failed send either races a legitimate teardown or
    /// precedes an error the next real wire call will surface.
    pub fn tick(&mut self, ep: &mut Endpoint) {
        let due = self.last.map_or(true, |t| t.elapsed() >= self.period);
        if due {
            let _ = ep.send(0, self.tag, vec![1]);
            self.last = Some(Instant::now());
        }
    }
}

/// Liveness-bounded idle wait for the leader's next `t`-tagged message.
///
/// Polls in `poll`-sized slices so the wait stays responsive, and
/// returns [`NetError::LeaderLost`] once `bound` elapses with no
/// leader traffic at all. While node 0 is alive this never fires: its
/// idle heartbeat period ([`LiveConfig::heartbeat_period`]) is several
/// times shorter than any sane `bound`. This is the liveness fix for
/// >2-node TCP meshes — the surviving followers' connections keep the
/// fabric open, so leader death used to be invisible to an idle
/// follower. A [`Beacon`], when provided, makes the liveness symmetric:
/// the follower proves ITS liveness to the idle leader on every poll
/// tick.
pub fn recv_from_leader(
    ep: &mut Endpoint,
    t: u64,
    bound: Duration,
    poll: Duration,
    mut beacon: Option<&mut Beacon>,
) -> Result<Envelope, NetError> {
    let deadline = Instant::now() + bound;
    loop {
        if let Some(b) = beacon.as_deref_mut() {
            b.tick(ep);
        }
        let left = deadline.saturating_duration_since(Instant::now());
        if left.is_zero() {
            return Err(NetError::LeaderLost(bound));
        }
        match ep.recv_tag(t, poll.min(left)) {
            Ok(env) => return Ok(env),
            Err(NetError::Timeout(_)) => continue,
            Err(e) => return Err(e),
        }
    }
}

/// Fold the runtime's per-token transfer meter into a breakdown.
fn note_transfers(b: &mut TokenBreakdown, rt: &NanoRuntime) {
    let ts = rt.take_transfer_stats();
    b.h2d_ns = ts.h2d_ns;
    b.d2h_ns = ts.d2h_ns;
    b.h2d_bytes = ts.h2d_bytes;
    b.d2h_bytes = ts.d2h_bytes;
    b.exec_calls = ts.exec_calls;
}

/// Fold the endpoint's per-token wire meter into a breakdown.
fn note_wire(b: &mut TokenBreakdown, ls: transport::LinkStats) {
    b.net_msgs = ls.msgs();
    b.net_bytes = ls.bytes();
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Regression (ROADMAP ">2-node TCP follower liveness"): in a
    /// 3-node loopback mesh, killing node 0 mid-idle must surface as
    /// `NetError::LeaderLost` on BOTH followers within the liveness
    /// bound. Before the heartbeat bound, the followers' own 1↔2
    /// connection kept each fabric channel open, so the idle wait span
    /// was unbounded — this test would hang.
    #[test]
    fn three_node_followers_detect_leader_death_mid_idle() {
        let bound = Duration::from_millis(600);
        let mut eps = crate::network::tcp::loopback_fabric(3).unwrap();
        let f2 = eps.pop().unwrap();
        let f1 = eps.pop().unwrap();
        let mut leader = eps.pop().unwrap();

        let follower = move |mut ep: Endpoint| {
            move || {
                // Replay the idle control plane the way `follow_decentralized`
                // does: heartbeats arrive in sequence until the leader dies.
                let mut seq = 0u32;
                let mut beats = 0;
                loop {
                    match recv_from_leader(
                        &mut ep,
                        tag(PHASE_CTRL, 0, seq),
                        bound,
                        Duration::from_millis(20),
                        None,
                    ) {
                        Ok(env) => {
                            assert_eq!(env.payload, vec![OP_HEARTBEAT]);
                            seq = seq.wrapping_add(1);
                            beats += 1;
                        }
                        Err(e) => return (beats, e),
                    }
                }
            }
        };
        let h1 = std::thread::spawn(follower(f1));
        let h2 = std::thread::spawn(follower(f2));

        // Node 0 heartbeats a few times while idle, then dies.
        for seq in 0..3u32 {
            leader.broadcast(tag(PHASE_CTRL, 0, seq), &[OP_HEARTBEAT]).unwrap();
            std::thread::sleep(Duration::from_millis(30));
        }
        let t_death = Instant::now();
        drop(leader);

        for h in [h1, h2] {
            let (beats, err) = h.join().unwrap();
            assert_eq!(beats, 3, "follower missed heartbeats");
            assert!(
                matches!(err, NetError::LeaderLost(_)),
                "expected LeaderLost, got {err:?}"
            );
        }
        let detect = t_death.elapsed();
        assert!(
            detect < bound + Duration::from_secs(2),
            "leader death took {detect:?} to detect (bound {bound:?})"
        );
    }

    /// The symmetric liveness satellite: a follower that dies mid-idle
    /// must be detectable by the idle leader via the beacon deadlines
    /// (before this, only the leader's next gather named a dead
    /// follower). A follower that keeps beaconing must NOT trip the
    /// bound, however long it idles.
    #[test]
    fn idle_leader_detects_follower_death_via_beacons() {
        let bound = Duration::from_millis(500);
        let mut eps = crate::network::tcp::loopback_fabric(3).unwrap();
        let f2 = eps.pop().unwrap();
        let mut f1 = eps.pop().unwrap();
        let mut leader = eps.pop().unwrap();

        // Follower 2 dies immediately, without a word; follower 1 keeps
        // beaconing the way its idle wait loop does.
        drop(f2);
        let stop = Arc::new(AtomicBool::new(false));
        let stop_f = stop.clone();
        let h = std::thread::spawn(move || {
            let mut b = Beacon::new(1, Duration::from_millis(50));
            while !stop_f.load(Ordering::Relaxed) {
                b.tick(&mut f1);
                std::thread::sleep(Duration::from_millis(20));
            }
        });

        // Leader side: drain beacons + check deadlines, exactly as the
        // idle heartbeat loop does.
        let mut heard = vec![Instant::now(); 3];
        let t0 = Instant::now();
        let missing = loop {
            for f in 1..3usize {
                while leader.recv_tag(beacon_tag(f), Duration::ZERO).is_ok() {
                    heard[f] = Instant::now();
                }
            }
            let overdue: Vec<usize> =
                (1..3).filter(|&f| heard[f].elapsed() > bound).collect();
            if !overdue.is_empty() {
                break overdue;
            }
            assert!(
                t0.elapsed() < bound + Duration::from_secs(3),
                "follower death never detected"
            );
            std::thread::sleep(Duration::from_millis(20));
        };
        assert_eq!(missing, vec![2], "only the dead follower may be overdue");
        // The live follower was never misread: detection took at least
        // the bound, during which its beacons kept arriving.
        assert!(t0.elapsed() >= bound);
        stop.store(true, Ordering::Relaxed);
        h.join().unwrap();
    }

    /// While heartbeats keep arriving, the bound never fires — liveness
    /// must not misread an idle-but-healthy leader as dead.
    #[test]
    fn heartbeats_keep_idle_followers_alive_past_the_bound() {
        let bound = Duration::from_millis(500);
        let mut eps = crate::network::tcp::loopback_fabric(2).unwrap();
        let mut follower_ep = eps.pop().unwrap();
        let mut leader = eps.pop().unwrap();
        let h = std::thread::spawn(move || {
            for seq in 0..6u32 {
                recv_from_leader(
                    &mut follower_ep,
                    tag(PHASE_CTRL, 0, seq),
                    bound,
                    Duration::from_millis(10),
                    None,
                )
                .expect("heartbeat arrived within the bound");
            }
        });
        // 6 beats spaced well under the bound: the total wait (600 ms)
        // exceeds the bound, but no single gap comes close to it.
        for seq in 0..6u32 {
            leader.broadcast(tag(PHASE_CTRL, 0, seq), &[OP_HEARTBEAT]).unwrap();
            std::thread::sleep(Duration::from_millis(100));
        }
        h.join().unwrap();
    }
}
