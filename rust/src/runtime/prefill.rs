//! Chunked prefill on the device-resident path: T consecutive prompt
//! positions of ONE request share each layer's dispatches.
//!
//! Serial prefill runs the full decode pipeline once per prompt token —
//! a per-layer dispatch train, a router d2h and an all-reduce round for
//! EVERY prompt position, which is what makes long-prompt admission
//! stall decode latency for everyone else. [`PrefillRun`] drives the
//! `dev_p{T}_*` artifact family (`aot.py::lower_prefill_artifacts`):
//! the chunk's residual stream is `[T, D]`, the K/V append writes T
//! rows at `pos..pos+T` in one dynamic-update-slice, and attention
//! applies a causal mask over the chunk (row t attends cache positions
//! `<= pos + t` — exactly the window a serial step at `pos + t` sees).
//!
//! # Identity with serial prefill
//!
//! The chunk chains off the SAME per-request `[Hkv, S, hd]` cache
//! buffers inside the request's [`DeviceState`]; nothing else about a
//! prompt position persists across tokens (decode embeds each token
//! fresh — the hidden state never carries over). So after a chunk the
//! caches are bit-identical to T serial appends, which makes chunked
//! and serial prefill produce identical downstream tokens (asserted by
//! `test_model.py::TestPrefillDecomposition` and end-to-end by the
//! chunked-vs-serial tests in `integration_cluster.rs`).
//!
//! # Ragged tails and padding rows
//!
//! A tail of fewer than T real tokens pads with token 0. Padding rows
//! write garbage K/V at `pos+real..pos+T`, but the causal mask keeps
//! every REAL row from attending there, and each of those positions is
//! overwritten by its real token's append before any later query
//! attends to it. Padding rows' expert slots carry weight 0. The one
//! hard precondition is `pos + T <= max_seq`: XLA's
//! dynamic-update-slice CLAMPS out-of-range start indices, which would
//! silently shift the write window — [`PrefillRun::begin`] rejects
//! chunks that do not fit instead.
//!
//! # No lm_head
//!
//! Prompt positions never produce logits. The LAST prompt token always
//! runs on the decode path (serial or batched), which is where lm_head
//! and sampling already live — so this module has no sampler coupling
//! at all.

use anyhow::{bail, Context, Result};

use crate::runtime::nano::NodeExperts;
use crate::runtime::{DeviceState, NanoRuntime};

/// Chunk sizes of the prefill artifact family, ascending — the rust
/// mirror of `aot.py::PREFILL_CHUNKS` (the manifest's
/// `prefill_chunk_max` is the source of truth at run time; this
/// constant pins the contract for the simulator and tests).
pub const PREFILL_CHUNKS: [usize; 2] = [8, 32];

/// One prefill chunk's forward pass: borrows the request's
/// [`DeviceState`] caches and chains the `dev_p{T}_*` executables
/// across layers. Dropped at the end of the chunk (the transient
/// x/h/moe_in activations die with it; the caches live on in the
/// request's state).
pub struct PrefillRun<'a> {
    chunk: usize,
    state: &'a mut DeviceState,
    real_rows: usize,
    /// Residual stream [T, D] (valid between `begin` and the last layer).
    x: Option<xla::PjRtBuffer>,
    /// Post-attention residual [T, D] (valid within a layer).
    h: Option<xla::PjRtBuffer>,
    /// Normed MoE input [T, D] (valid within a layer).
    moe_in: Option<xla::PjRtBuffer>,
    /// First row's sequence position, uploaded once per chunk (i32[]).
    pos_buf: xla::PjRtBuffer,
}

impl<'a> PrefillRun<'a> {
    /// Embed `tokens` (the chunk's prompt slice, `1..=chunk` of them —
    /// shorter slices pad with token 0) into a `[T, D]` residual stream
    /// at sequence positions `pos..pos+tokens.len()`.
    pub fn begin(
        rt: &NanoRuntime,
        chunk: usize,
        state: &'a mut DeviceState,
        tokens: &[u32],
        pos: usize,
    ) -> Result<PrefillRun<'a>> {
        let rows = tokens.len();
        if rows == 0 || rows > chunk {
            bail!("{rows} prompt tokens do not fit prefill chunk {chunk}");
        }
        // dynamic-update-slice CLAMPS an out-of-range start index, which
        // would silently shift the whole write window — refuse instead
        // (the scheduler falls back to serial steps near max_seq).
        if pos + chunk > rt.manifest.max_seq {
            bail!(
                "prefill chunk {chunk} at pos {pos} exceeds max_seq {}",
                rt.manifest.max_seq
            );
        }
        let _sp = crate::obs::span("prefill.begin")
            .arg("chunk", chunk as u64)
            .arg("rows", rows as u64);
        let exes = rt.prefill(chunk)?;
        let mut toks = vec![0i32; chunk]; // padding rows feed token 0
        for (r, &t) in tokens.iter().enumerate() {
            toks[r] = t as i32;
        }
        let tok_buf = rt.buf_i32(&toks, &[chunk])?;
        let x = rt.run_dev(&exes.embed, &[rt.embed_weight_buf(), &tok_buf])?;
        let pos_buf = rt.buf_i32(&[pos as i32], &[])?;
        Ok(PrefillRun {
            chunk,
            state,
            real_rows: rows,
            x: Some(x),
            h: None,
            moe_in: None,
            pos_buf,
        })
    }

    pub fn chunk(&self) -> usize {
        self.chunk
    }

    /// Real prompt rows in the chunk (the rest is padding).
    pub fn rows(&self) -> usize {
        self.real_rows
    }

    /// One layer's attention + routing for the whole chunk: one bulk
    /// K/V append pair, shared attention/norm/router dispatches, ONE
    /// packed `[T, 2K]` top-k download. Returns `(top_w, top_i)` per
    /// REAL row (padding rows' routing is discarded — their expert
    /// slots get weight 0 from the planner).
    #[allow(clippy::type_complexity)]
    pub fn attn_router(
        &mut self,
        rt: &NanoRuntime,
        layer: usize,
    ) -> Result<Vec<(Vec<f32>, Vec<usize>)>> {
        let _sp = crate::obs::span("prefill.attn_router").arg("layer", layer as u64);
        let exes = rt.prefill(self.chunk)?;
        let w = rt.attn_weights(layer);
        let (ln1, wqkv, wo, ln2, wr) = (&w[0], &w[1], &w[2], &w[3], &w[4]);
        let x = self.x.take().context("begin not called")?;
        let qkv = rt.run_dev(&exes.qkv, &[ln1, wqkv, &x])?;

        // ONE append per cache side writes all T rows (vs T per side on
        // the serial path) — the dispatch amortization this family buys.
        let kc = self.state.k[layer].take().context("cache buffer missing")?;
        let vc = self.state.v[layer].take().context("cache buffer missing")?;
        let new_k = rt.run_dev(&exes.k_append, &[&kc, &qkv, &self.pos_buf])?;
        let new_v = rt.run_dev(&exes.v_append, &[&vc, &qkv, &self.pos_buf])?;
        let h = rt.run_dev(&exes.attn_out, &[wo, &x, &qkv, &new_k, &new_v, &self.pos_buf])?;
        self.state.k[layer] = Some(new_k);
        self.state.v[layer] = Some(new_v);

        let moe_in = rt.run_dev(&exes.moe_norm, &[ln2, &h])?;
        let packed_buf = rt.run_dev(&exes.router, &[wr, &moe_in])?;
        let topk_sp = crate::obs::span("router.topk_d2h").arg("layer", layer as u64);
        let packed = rt.download_f32(&packed_buf)?;
        drop(topk_sp);

        self.x = Some(x);
        self.h = Some(h);
        self.moe_in = Some(moe_in);

        let k = rt.manifest.top_k;
        if packed.len() != self.chunk * 2 * k {
            bail!("router returned {} values, expected {}", packed.len(), self.chunk * 2 * k);
        }
        let mut draws = Vec::with_capacity(self.real_rows);
        for r in 0..self.real_rows {
            let row = &packed[r * 2 * k..(r + 1) * 2 * k];
            let top_w = row[..k].to_vec();
            let top_i = row[k..].iter().map(|&f| f.round() as usize).collect();
            draws.push((top_w, top_i));
        }
        Ok(draws)
    }

    /// Download the current `[T, D]` MoE input (centralized leader
    /// only: the scatter payload must hit the wire — one message now
    /// carries the whole chunk).
    pub fn moe_in_host(&self, rt: &NanoRuntime) -> Result<Vec<f32>> {
        let b = self.moe_in.as_ref().context("no moe_in: run attn_router first")?;
        rt.download_f32(b)
    }

    /// Run this node's experts for ALL chunk rows in one dispatch:
    /// `slot_idx` / `slot_w` are `[chunk * ns]` row-major per-row local
    /// slot assignments (weight 0 on padding slots and padding rows).
    /// The `[T, D]` partial stays on device.
    pub fn node_experts(
        &mut self,
        rt: &NanoRuntime,
        node: &NodeExperts,
        layer: usize,
        slot_idx: &[i32],
        slot_w: &[f32],
    ) -> Result<xla::PjRtBuffer> {
        if slot_idx.len() != slot_w.len() || slot_idx.len() % self.chunk != 0 {
            bail!("slot_idx/slot_w shape mismatch");
        }
        let _sp = crate::obs::span("prefill.experts").arg("layer", layer as u64);
        let ns = slot_idx.len() / self.chunk;
        let exes = rt.prefill(self.chunk)?;
        let exe = exes.experts_exe(node.resident.len(), ns, &rt.manifest)?;
        let moe_in = self.moe_in.take().context("no moe_in: run attn_router first")?;
        let le = &node.layers[layer];
        let ib = rt.buf_i32(slot_idx, &[self.chunk, ns])?;
        let wb = rt.buf_f32(slot_w, &[self.chunk, ns])?;
        let partial = rt.run_dev(exe, &[&le.w1, &le.v1, &le.w2, &moe_in, &ib, &wb])?;
        self.moe_in = Some(moe_in);
        Ok(partial)
    }

    /// Close the layer with a `[T, D]` sum that is already on device
    /// (single-node case: the local partial IS the sum).
    pub fn finish_layer_device(
        &mut self,
        rt: &NanoRuntime,
        moe_sum: &xla::PjRtBuffer,
    ) -> Result<()> {
        let exes = rt.prefill(self.chunk)?;
        let h = self.h.take().context("no h: run attn_router first")?;
        self.x = Some(rt.run_dev(&exes.residual, &[&h, moe_sum])?);
        self.moe_in = None;
        Ok(())
    }

    /// Close the layer with a host-side `[T * D]` sum (multi-node: the
    /// all-reduced rows came off the wire in one payload).
    pub fn finish_layer_host(&mut self, rt: &NanoRuntime, moe_sum: &[f32]) -> Result<()> {
        let d = rt.manifest.d_embed;
        if moe_sum.len() != self.chunk * d {
            bail!("moe sum has {} elements, expected {}", moe_sum.len(), self.chunk * d);
        }
        let sum = rt.buf_f32(moe_sum, &[self.chunk, d])?;
        self.finish_layer_device(rt, &sum)
    }
}
