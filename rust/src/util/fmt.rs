//! Human-readable formatting of byte counts and nanosecond durations.

/// Format a byte count with binary units: `1536 -> "1.50 KiB"`.
pub fn format_bytes(bytes: u64) -> String {
    const UNITS: [&str; 6] = ["B", "KiB", "MiB", "GiB", "TiB", "PiB"];
    if bytes < 1024 {
        return format!("{bytes} B");
    }
    let mut v = bytes as f64;
    let mut unit = 0;
    while v >= 1024.0 && unit < UNITS.len() - 1 {
        v /= 1024.0;
        unit += 1;
    }
    format!("{v:.2} {}", UNITS[unit])
}

/// Format a nanosecond duration at an appropriate scale.
pub fn format_duration_ns(ns: u64) -> String {
    if ns < 1_000 {
        format!("{ns} ns")
    } else if ns < 1_000_000 {
        format!("{:.2} µs", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2} ms", ns as f64 / 1e6)
    } else {
        format!("{:.3} s", ns as f64 / 1e9)
    }
}

/// Left-pad / right-align helpers for plain-text tables.
pub fn pad_left(s: &str, width: usize) -> String {
    if s.len() >= width {
        s.to_string()
    } else {
        format!("{}{}", " ".repeat(width - s.len()), s)
    }
}

pub fn pad_right(s: &str, width: usize) -> String {
    if s.len() >= width {
        s.to_string()
    } else {
        format!("{}{}", s, " ".repeat(width - s.len()))
    }
}

/// Render a simple aligned text table: first row is the header.
/// Numeric-looking cells are right-aligned, text cells left-aligned.
pub fn render_table(rows: &[Vec<String>]) -> String {
    if rows.is_empty() {
        return String::new();
    }
    let cols = rows.iter().map(|r| r.len()).max().expect("rows is non-empty here");
    let mut widths = vec![0usize; cols];
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            widths[i] = widths[i].max(cell.len());
        }
    }
    let numeric = |s: &str| {
        !s.is_empty()
            && s.chars()
                .all(|c| c.is_ascii_digit() || ".-+e%×x/<".contains(c))
    };
    let mut out = String::new();
    for (ri, row) in rows.iter().enumerate() {
        let mut line = String::new();
        for (i, cell) in row.iter().enumerate() {
            let cell = if ri > 0 && numeric(cell) {
                pad_left(cell, widths[i])
            } else {
                pad_right(cell, widths[i])
            };
            line.push_str(&cell);
            if i + 1 < row.len() {
                line.push_str("  ");
            }
        }
        out.push_str(line.trim_end());
        out.push('\n');
        if ri == 0 {
            for (i, w) in widths.iter().enumerate() {
                out.push_str(&"-".repeat(*w));
                if i + 1 < widths.len() {
                    out.push_str("  ");
                }
            }
            out.push('\n');
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_units() {
        assert_eq!(format_bytes(0), "0 B");
        assert_eq!(format_bytes(1023), "1023 B");
        assert_eq!(format_bytes(1536), "1.50 KiB");
        assert_eq!(format_bytes(1024 * 1024), "1.00 MiB");
        assert_eq!(format_bytes(192 * 1024 * 1024 * 1024), "192.00 GiB");
    }

    #[test]
    fn duration_units() {
        assert_eq!(format_duration_ns(999), "999 ns");
        assert_eq!(format_duration_ns(1_500), "1.50 µs");
        assert_eq!(format_duration_ns(2_500_000), "2.50 ms");
        assert_eq!(format_duration_ns(1_166_000_000), "1.166 s");
    }

    #[test]
    fn table_alignment() {
        let t = render_table(&[
            vec!["name".into(), "tp".into()],
            vec!["naive".into(), "1.2".into()],
            vec!["p-lr-d".into(), "6.1".into()],
        ]);
        assert!(t.contains("name"));
        assert!(t.lines().count() == 4);
        // numeric column right-aligned
        let lines: Vec<&str> = t.lines().collect();
        assert!(lines[2].ends_with("1.2"));
        assert!(lines[3].ends_with("6.1"));
    }

    #[test]
    fn pad_functions() {
        assert_eq!(pad_left("ab", 4), "  ab");
        assert_eq!(pad_right("ab", 4), "ab  ");
        assert_eq!(pad_left("abcd", 2), "abcd");
    }
}
