//! `apple-moe serve` — LIVE serving driver on the streaming API: submit
//! a batch of synthetic requests, interleave them with the
//! iteration-level scheduler (`--concurrency`), stream tokens as they
//! decode, and report per-request TTFT / queueing / latency plus the
//! aggregate. `--json` emits the machine-readable per-request report CI
//! tracks (the BENCH_serve.json perf trajectory); `--transport tcp`
//! runs the node mesh over real loopback sockets; `--host-sampler`
//! forces the `[B, V]` logits download + host reference sampler (the
//! default samples on device — `d2h_bytes_per_token` in the JSON
//! report meters the collapse); `--prefill-chunk` caps the chunked
//! prefill size (1 = serial token-by-token prompts — the JSON report's
//! `prefill_tps` / `prefill_exec_calls_per_token` meter the difference).
//! `--prompt-tokens` / `--gen-tokens` take a single length or a
//! comma-separated cycle ("96,4,4": request i gets the i-mod-3rd
//! length) so one invocation can mix a long prompt into a
//! short-request stream — the workload the chunked-prefill decode-tail
//! bench drives.

use anyhow::Result;
use std::time::{Duration, Instant};

use crate::cli::args::Args;
use crate::cli::commands::{
    artifacts_dir, drain_handles, parse_balancing, parse_policy, parse_sampling,
    parse_topology,
};
use crate::cluster::live::{LiveCluster, LiveConfig, TransportKind};
use crate::engine::request::{Request, RequestResult};
use crate::metrics::PhaseMetrics;
use crate::util::fmt::render_table;
use crate::util::stats::Summary;

pub fn run(args: &mut Args) -> Result<()> {
    let nodes = args.usize_or("nodes", 2)?;
    let n_requests = args.usize_or("requests", 4)?;
    let prompt_cycle = parse_len_cycle("prompt-tokens", &args.str_or("prompt-tokens", "16"))?;
    let gen_cycle = parse_len_cycle("gen-tokens", &args.str_or("gen-tokens", "32"))?;
    let concurrency = args.usize_or("concurrency", 2)?;
    let prefill_chunk = args.usize_or("prefill-chunk", 32)?;
    let policy = parse_policy(args)?;
    let transport = match args.str_or("transport", "inproc").as_str() {
        "inproc" | "in-process" => TransportKind::InProcess,
        "tcp" => TransportKind::TcpLoopback,
        other => anyhow::bail!("unknown transport '{other}' (inproc|tcp)"),
    };
    let topology = parse_topology(args)?;
    let balancing = parse_balancing(args)?;
    let recv_timeout = args.u64_or("recv-timeout-secs", 120)?;
    let host_path = args.flag("host-path");
    let host_sampler = args.flag("host-sampler");
    let stream = args.flag("stream");
    let json = args.flag("json");
    let trace_out = args.get("trace-out").map(std::path::PathBuf::from);
    let sampling = parse_sampling(args, gen_cycle[0])?;
    let dir = artifacts_dir(args);
    args.finish()?;
    anyhow::ensure!(n_requests >= 1, "--requests must be >= 1");
    anyhow::ensure!(concurrency >= 1, "--concurrency must be >= 1");

    let mut cfg = LiveConfig::new(dir, nodes);
    cfg.topology = topology;
    cfg.balancing = balancing;
    cfg.device_resident = !host_path;
    cfg.host_sampler = host_sampler;
    cfg.recv_timeout = Duration::from_secs(recv_timeout.max(1));
    cfg.max_active = concurrency;
    cfg.policy = policy;
    cfg.prefill_chunk = prefill_chunk;
    cfg.transport = transport;
    cfg.trace = trace_out;

    eprintln!(
        "starting {nodes}-node live cluster ({} transport, concurrency {concurrency})...",
        match transport {
            TransportKind::InProcess => "in-process",
            TransportKind::TcpLoopback => "loopback-tcp",
        }
    );
    let cluster = LiveCluster::start(cfg)?;

    // Submit everything up front: the scheduler admits `concurrency`
    // requests at a time, so later submissions meter real queueing
    // delay while earlier ones interleave their decode iterations.
    let t_all = Instant::now();
    let mut handles = Vec::with_capacity(n_requests);
    for i in 0..n_requests {
        let prompt_tokens = prompt_cycle[i % prompt_cycle.len()];
        let gen_tokens = gen_cycle[i % gen_cycle.len()];
        let mut req = Request::synthetic(i as u64, prompt_tokens, 512, gen_tokens);
        let mut s = sampling.clone();
        s.seed ^= i as u64; // per-request sampler stream
        s.max_new_tokens = gen_tokens;
        req.sampling = s;
        handles.push(cluster.submit(req)?);
    }

    // Drain all event streams as tokens decode. The inactivity bound
    // backstops a wedged-but-alive cluster — a hung accelerator call
    // that no wire timeout can see.
    let idle_limit = Duration::from_secs(recv_timeout.max(1)).saturating_mul(2);
    let results = drain_handles(&handles, stream, json, idle_limit)?;
    let wall = t_all.elapsed().as_secs_f64();
    cluster.shutdown();
    if json {
        println!("{}", json_report(&results, wall, nodes, concurrency));
        return Ok(());
    }

    let mut rows = vec![vec![
        "req".to_string(),
        "queue (s)".to_string(),
        "ttft (s)".to_string(),
        "latency (s)".to_string(),
        "prefill tok/s".to_string(),
        "decode tok/s".to_string(),
        "occupancy".to_string(),
    ]];
    let mut decode_tps = Vec::new();
    let mut total_tokens = 0usize;
    for r in &results {
        total_tokens += r.generated.len();
        decode_tps.push(r.metrics.decode.tokens_per_sec());
        rows.push(vec![
            r.id.to_string(),
            format!("{:.2}", r.metrics.queueing_s()),
            format!("{:.2}", r.metrics.ttft_s()),
            format!("{:.2}", r.metrics.latency_s()),
            format!("{:.1}", r.metrics.prefill.tokens_per_sec()),
            format!("{:.1}", r.metrics.decode.tokens_per_sec()),
            format!("{:.2}", r.metrics.decode.mean_batch_occupancy()),
        ]);
    }
    print!("{}", render_table(&rows));
    if let Some(s) = Summary::of(&decode_tps) {
        println!(
            "\n{n_requests} requests, {total_tokens} generated tokens in {wall:.2} s \
             ({:.1} tok/s aggregate, concurrency {concurrency}, {policy:?})",
            total_tokens as f64 / wall
        );
        println!(
            "decode throughput per request: mean {:.1} / p50 {:.1} / min {:.1} tok/s",
            s.mean, s.p50, s.min
        );
    }
    Ok(())
}

/// Parse `--prompt-tokens` / `--gen-tokens`: a single length ("16") or
/// a comma-separated cycle ("96,4,4") assigned round-robin across
/// requests.
fn parse_len_cycle(flag: &str, spec: &str) -> Result<Vec<usize>> {
    let cycle: Vec<usize> = spec
        .split(',')
        .map(|v| {
            let v = v.trim();
            v.parse::<usize>().map_err(|_| {
                anyhow::anyhow!("--{flag} expects integers, got '{v}' in '{spec}'")
            })
        })
        .collect::<Result<_>>()?;
    anyhow::ensure!(!cycle.is_empty(), "--{flag} must list at least one length");
    anyhow::ensure!(
        cycle.iter().all(|&t| t >= 1),
        "--{flag} lengths must be >= 1 (got '{spec}')"
    );
    Ok(cycle)
}

/// Hand-rolled JSON (the offline crate cache has no serde): one record
/// per request plus the aggregates, parsed by CI's multiproc-smoke job.
/// Shared with `apple-moe client` (the BENCH_remote_serve.json report
/// has the same shape).
pub(crate) fn json_report(
    results: &[RequestResult],
    wall_s: f64,
    nodes: usize,
    concurrency: usize,
) -> String {
    let total: usize = results.iter().map(|r| r.generated.len()).sum();
    let mut s = String::from("{\"requests\":[");
    for (i, r) in results.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        let d = &r.metrics.decode;
        let p = &r.metrics.prefill;
        s.push_str(&format!(
            "{{\"id\":{},\"ttft_s\":{:.6},\"queueing_s\":{:.6},\"latency_s\":{:.6},\
             \"prefill_tps\":{:.3},\"prefill_exec_calls_per_token\":{:.2},\
             \"decode_tps\":{:.3},\"generated\":{},\"net_bytes\":{},\
             \"mean_occupancy\":{:.3},\"exec_calls_per_token\":{:.2},\
             \"d2h_bytes_per_token\":{:.1}}}",
            r.id,
            r.metrics.ttft_s(),
            r.metrics.queueing_s(),
            r.metrics.latency_s(),
            p.tokens_per_sec(),
            p.exec_calls_per_token(),
            d.tokens_per_sec(),
            r.generated.len(),
            d.net_bytes + p.net_bytes,
            d.mean_batch_occupancy(),
            d.exec_calls_per_token(),
            d.d2h_bytes_per_token(),
        ));
    }
    // Aggregate occupancy: decode-token-weighted mean over the batch
    // (1.0 = serial; → concurrency under a saturated batched scheduler).
    let (occ_sum, occ_tokens) = results.iter().fold((0.0f64, 0u64), |(s, n), r| {
        let d = &r.metrics.decode;
        (s + d.mean_batch_occupancy() * d.tokens as f64, n + d.tokens)
    });
    // Aggregate tails: ONE merged decode phase across requests (the
    // tail histograms merge exactly), exact across-request TTFT /
    // queueing percentiles, and total mesh wire traffic — so the
    // BENCH_*.json trajectory tracks p99s and bytes-on-the-wire, not
    // just means.
    let mut agg = PhaseMetrics::default();
    let mut agg_prefill = PhaseMetrics::default();
    let mut ttfts: Vec<f64> = Vec::with_capacity(results.len());
    let mut queues: Vec<f64> = Vec::with_capacity(results.len());
    let (mut net_msgs, mut net_bytes) = (0u64, 0u64);
    for r in results {
        agg.merge(&r.metrics.decode);
        agg_prefill.merge(&r.metrics.prefill);
        ttfts.push(r.metrics.ttft_s());
        queues.push(r.metrics.queueing_s());
        net_msgs += r.metrics.prefill.net_msgs + r.metrics.decode.net_msgs;
        net_bytes += r.metrics.prefill.net_bytes + r.metrics.decode.net_bytes;
    }
    ttfts.sort_by(f64::total_cmp);
    queues.sort_by(f64::total_cmp);
    s.push_str(&format!(
        "],\"nodes\":{nodes},\"concurrency\":{concurrency},\"wall_s\":{wall_s:.6},\
         \"aggregate_tps\":{:.3},\"prefill_tps\":{:.3},\
         \"prefill_exec_calls_per_token\":{:.2},\"net_msgs_total\":{net_msgs},\
         \"net_bytes_total\":{net_bytes},\"token_latency_s\":{},\"comm_s\":{},\
         \"d2h_s\":{},\"ttft_s\":{},\"queueing_s\":{},\"mean_occupancy\":{:.3}}}",
        if wall_s > 0.0 { total as f64 / wall_s } else { 0.0 },
        agg_prefill.tokens_per_sec(),
        agg_prefill.exec_calls_per_token(),
        quantile_json(agg.token_latency_quantiles_s()),
        quantile_json(agg.comm_quantiles_s()),
        quantile_json(agg.d2h_quantiles_s()),
        quantile_json((pct(&ttfts, 0.5), pct(&ttfts, 0.9), pct(&ttfts, 0.99))),
        quantile_json((pct(&queues, 0.5), pct(&queues, 0.9), pct(&queues, 0.99))),
        if occ_tokens > 0 { occ_sum / occ_tokens as f64 } else { 1.0 },
    ));
    s
}

/// `{"p50":…,"p90":…,"p99":…}` for a quantile triple in seconds.
fn quantile_json((p50, p90, p99): (f64, f64, f64)) -> String {
    format!("{{\"p50\":{p50:.6},\"p90\":{p90:.6},\"p99\":{p99:.6}}}")
}

/// Exact percentile of a sorted sample (nearest-rank; 0.0 when empty).
fn pct(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((sorted.len() as f64 - 1.0) * q).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::request::FinishReason;
    use crate::metrics::RunMetrics;

    #[test]
    fn len_cycle_parses_single_and_mixed() {
        assert_eq!(parse_len_cycle("prompt-tokens", "16").unwrap(), vec![16]);
        assert_eq!(parse_len_cycle("prompt-tokens", "96,4,4").unwrap(), vec![96, 4, 4]);
        assert_eq!(parse_len_cycle("gen-tokens", " 8 , 2 ").unwrap(), vec![8, 2]);
        assert!(parse_len_cycle("prompt-tokens", "").is_err());
        assert!(parse_len_cycle("prompt-tokens", "4,zero").is_err());
        assert!(parse_len_cycle("gen-tokens", "4,0").is_err());
    }

    #[test]
    fn json_report_shape() {
        let m = RunMetrics {
            queueing_ns: 5_000_000,
            ttft_ns: 100_000_000,
            latency_ns: 900_000_000,
            ..Default::default()
        };
        let r = RequestResult {
            id: 0,
            generated: vec![1, 2, 3],
            finish: FinishReason::Length,
            metrics: m,
        };
        let j = json_report(&[r], 1.5, 2, 2);
        assert!(j.starts_with('{') && j.ends_with('}'), "{j}");
        for key in [
            "\"requests\":[",
            "\"ttft_s\":0.100000",
            "\"queueing_s\":0.005000",
            "\"latency_s\":0.900000",
            "\"prefill_tps\":",
            "\"prefill_exec_calls_per_token\":",
            "\"decode_tps\":",
            "\"net_bytes\":",
            "\"generated\":3",
            "\"mean_occupancy\":",
            "\"exec_calls_per_token\":",
            "\"d2h_bytes_per_token\":",
            "\"nodes\":2",
            "\"concurrency\":2",
            "\"aggregate_tps\":2.000",
            "\"net_msgs_total\":",
            "\"net_bytes_total\":",
            "\"token_latency_s\":{\"p50\":",
            "\"comm_s\":{\"p50\":",
            "\"d2h_s\":{\"p50\":",
            "\"ttft_s\":{\"p50\":0.100000,\"p90\":0.100000,\"p99\":0.100000}",
            "\"queueing_s\":{\"p50\":0.005000",
        ] {
            assert!(j.contains(key), "missing {key} in {j}");
        }
    }

    #[test]
    fn json_report_tail_quantiles_see_the_straggler() {
        // 100 decode tokens, 10 of them 100× slower: the aggregate p50
        // stays fast while p99 reports the straggler tail — the whole
        // point of shipping histograms instead of means.
        use crate::metrics::TokenBreakdown;
        let mut m = RunMetrics::default();
        for i in 0..100u64 {
            let slow = i % 10 == 9;
            m.decode.push(TokenBreakdown {
                misc_ns: if slow { 200_000_000 } else { 2_000_000 },
                ..Default::default()
            });
        }
        let r = RequestResult {
            id: 0,
            generated: vec![1; 100],
            finish: FinishReason::Length,
            metrics: m,
        };
        let j = json_report(&[r], 1.0, 1, 1);
        let grab = |key: &str| -> (f64, f64, f64) {
            let at = j.find(key).unwrap_or_else(|| panic!("missing {key} in {j}"));
            let obj = &j[at + key.len()..];
            let end = obj.find('}').unwrap();
            let mut vals = obj[..end].split(',').map(|kv| {
                kv.split(':').nth(1).unwrap().parse::<f64>().unwrap()
            });
            (vals.next().unwrap(), vals.next().unwrap(), vals.next().unwrap())
        };
        let (p50, p90, p99) = grab("\"token_latency_s\":{");
        assert!(p50 < 0.01, "p50 {p50} should sit with the fast tokens");
        assert!(p99 > 0.1, "p99 {p99} should see the straggler");
        assert!(p50 <= p90 && p90 <= p99);
    }

    #[test]
    fn json_report_aggregates_occupancy() {
        // Two requests whose decode phases ran at occupancy 4 and 2 for
        // 3 and 1 tokens respectively: the aggregate is token-weighted.
        use crate::metrics::TokenBreakdown;
        let mk = |occ: u32, tokens: usize, id: u64| {
            let mut m = RunMetrics::default();
            for _ in 0..tokens {
                m.decode.push(TokenBreakdown { batch_rows: occ, ..Default::default() });
            }
            RequestResult {
                id,
                generated: vec![0; tokens],
                finish: FinishReason::Length,
                metrics: m,
            }
        };
        let j = json_report(&[mk(4, 3, 0), mk(2, 1, 1)], 1.0, 2, 4);
        assert!(j.contains("\"mean_occupancy\":4.000"), "{j}");
        assert!(j.contains("\"mean_occupancy\":2.000"), "{j}");
        // (4*3 + 2*1) / 4 = 3.5 aggregate.
        assert!(j.ends_with("\"mean_occupancy\":3.500}"), "{j}");
    }
}
