"""AOT lowering: JAX -> HLO *text* artifacts + weight bundle.

The rust runtime (`rust/src/runtime/`) loads these with
``HloModuleProto::from_text_file`` on the PJRT CPU client. Text — NOT
``.serialize()`` — is the interchange format: jax >= 0.5 emits protos with
64-bit instruction ids that the crate's xla_extension 0.5.1 rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids.

Emitted into ``artifacts/`` (idempotent; `make artifacts` skips when
fresh):

  embed.hlo.txt         (embed, tok)                      -> (x,)
  attn_router.hlo.txt   (ln1,wqkv,wo,ln2,wr,x,k,v,pos)    -> (h, moe_in, top_w, top_i, k', v')
  experts_el8.hlo.txt   ([8,..] stacks, moe_in, idx, w)   -> (partial,)
  experts_el16.hlo.txt  ([16,..] stacks, moe_in, idx, w)  -> (partial,)
  lm_head.hlo.txt       (ln_f, lm_head, h)                -> (logits,)
  dense_step.hlo.txt    (params..., tok, K, V, pos)       -> (logits, K', V')
  dev_*.hlo.txt         single-output UNTUPLED roles for the
                        device-resident decode path (see
                        `lower_device_artifacts`) — buffers chain between
                        executables without host staging
  dev_b{B}_*.hlo.txt    the BATCHED family of the same roles at leading
                        dim B in BATCH_BUCKETS (see
                        `lower_batched_artifacts`): B concurrent
                        requests share one forward pass per scheduler
                        iteration (continuous batching)
  dev_p{T}_*.hlo.txt    the chunked PREFILL family at chunk sizes T in
                        PREFILL_CHUNKS (see `lower_prefill_artifacts`):
                        T consecutive prompt positions of one request
                        share each layer's dispatches; no lm_head role
                        (prompt positions never produce logits)
  dev[_b{B}]_sample_*.hlo.txt
                        on-device sampler roles (greedy / seeded top-k /
                        stop mask) chained off the lm_head buffer so a
                        decode iteration downloads [B, 2] + [B] instead
                        of [B, V] logits (see `lower_sampler_artifacts`)
  weights.npz           all model weights (float32, flat names)
  manifest.txt          dims + artifact inventory for the rust side
"""

import argparse
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from compile import model as M
from compile.model import CFG, NUM_SLOTS


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def to_hlo_text_untupled(lowered) -> str:
    """Lower a SINGLE-output computation with an ARRAY root (no tuple).

    PJRT returns a tuple root as one opaque buffer that can only be read
    through a host literal, so tuple-rooted artifacts force a device->host
    round trip per call. With ``return_tuple=False`` the root is the array
    itself and ``execute`` hands back a plain buffer the rust coordinator
    can chain into the next executable — the contract of every ``dev_*``
    (device-resident) artifact.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=False
    )
    return comp.as_hlo_text()


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def i32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def lower_artifacts(cfg=CFG):
    """Return {name: hlo_text} for every role computation."""
    d, dq, f, e, k = cfg.d_embed, cfg.d_qkv, cfg.d_ffn, cfg.n_experts, cfg.top_k
    nh, nk, hd, s, v, nl = (
        cfg.n_heads,
        cfg.n_kv_heads,
        cfg.head_dim,
        cfg.max_seq,
        cfg.vocab,
        cfg.n_layers,
    )
    arts = {}

    arts["embed"] = to_hlo_text(
        jax.jit(lambda t_, tok: (M.embed_step(t_, tok),)).lower(f32(v, d), i32(1))
    )

    def attn_router(ln1, wqkv, wo, ln2, wr, x, kc, vc, pos):
        return M.attn_router_step(ln1, wqkv, wo, ln2, wr, x, kc, vc, pos, cfg)

    arts["attn_router"] = to_hlo_text(
        jax.jit(attn_router).lower(
            f32(d), f32(d, dq), f32(nh * hd, d), f32(d), f32(d, e),
            f32(1, d), f32(nk, s, hd), f32(nk, s, hd), i32(),
        )
    )

    def experts(w1s, v1s, w2s, x, idx, w):
        return (M.experts_forward(w1s, v1s, w2s, x, idx, w),)

    def experts_fast(w1s, v1s, w2s, x, idx, w):
        return (M.experts_forward_fast(w1s, v1s, w2s, x, idx, w),)

    # Reference path: the L1 Pallas kernel (gridded, TPU-shaped).
    for el in (8, 16):
        arts[f"experts_el{el}"] = to_hlo_text(
            jax.jit(experts).lower(
                f32(el, d, f), f32(el, d, f), f32(el, f, d),
                f32(1, d), i32(NUM_SLOTS), f32(NUM_SLOTS),
            )
        )
    # Serving path: the fast slot-loop formulation (see §Perf), at
    # NS = top_k for router-aided/selected-only and NS = NUM_SLOTS for
    # busy-full.
    for el in (8, 16):
        for ns in (k, NUM_SLOTS):
            arts[f"experts_el{el}_fast_ns{ns}"] = to_hlo_text(
                jax.jit(experts_fast).lower(
                    f32(el, d, f), f32(el, d, f), f32(el, f, d),
                    f32(1, d), i32(ns), f32(ns),
                )
            )

    # Fastest serving path: per-slot weights as direct arguments (the
    # coordinator owns per-expert buffers) — no gather, no slicing.
    def experts_direct(x, w, *ws):
        return (M.experts_forward_direct(x, w, *ws),)

    for ns in (k, NUM_SLOTS):
        wspecs = []
        for _ in range(ns):
            wspecs += [f32(d, f), f32(d, f), f32(f, d)]
        arts[f"experts_direct_ns{ns}"] = to_hlo_text(
            jax.jit(experts_direct).lower(f32(1, d), f32(ns), *wspecs)
        )

    arts["lm_head"] = to_hlo_text(
        jax.jit(lambda a, b, h: (M.lm_head_step(a, b, h),)).lower(
            f32(d), f32(d, v), f32(1, d)
        )
    )

    order = M.dense_param_order(cfg)
    p0 = M.init_params(cfg)
    param_specs = [f32(*p0[kk].shape) for kk in order]

    def dense(*args):
        params = list(args[: len(order)])
        tok, kc, vc, pos = args[len(order) :]
        return M.dense_decode_step(params, tok, kc, vc, pos, cfg)

    arts["dense_step"] = to_hlo_text(
        jax.jit(dense).lower(
            *param_specs, i32(1), f32(nl, nk, s, hd), f32(nl, nk, s, hd), i32()
        )
    )
    return arts


def lower_device_artifacts(cfg=CFG, donate_caches=False):
    """Return {name: hlo_text} for the ``dev_*`` device-resident roles.

    Every artifact here has exactly one output and is lowered UNTUPLED so
    the rust runtime keeps the result as a `PjRtBuffer` (see
    `to_hlo_text_untupled`). Together they decompose `attn_router` such
    that the K/V caches and the x/h/moe_in activations never cross the
    host boundary during decode; only `dev_router`'s packed [2K] top-k and
    the expert partial (the all-reduce payload) are downloaded.

    ``donate_caches=True`` adds input/output aliasing (donation) hints on
    the cache-append roles so PJRT may update the cache in place. Off by
    default: the rust `execute` wrapper does not mark its argument buffers
    donatable, and CPU PJRT rejects donation of externally referenced
    buffers at run time.
    """
    d, dq, e, k = cfg.d_embed, cfg.d_qkv, cfg.n_experts, cfg.top_k
    nh, nk, hd, s, v = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim, cfg.max_seq, cfg.vocab
    arts = {}

    arts["dev_embed"] = to_hlo_text_untupled(
        jax.jit(M.embed_step).lower(f32(v, d), i32(1))
    )
    arts["dev_qkv"] = to_hlo_text_untupled(
        jax.jit(M.qkv_step).lower(f32(d), f32(d, dq), f32(1, d))
    )
    donate = dict(donate_argnums=(0,)) if donate_caches else {}
    arts["dev_k_append"] = to_hlo_text_untupled(
        jax.jit(M.k_append_step, **donate).lower(f32(nk, s, hd), f32(1, dq), i32())
    )
    arts["dev_v_append"] = to_hlo_text_untupled(
        jax.jit(M.v_append_step, **donate).lower(f32(nk, s, hd), f32(1, dq), i32())
    )
    arts["dev_attn_out"] = to_hlo_text_untupled(
        jax.jit(M.attn_out_step).lower(
            f32(nh * hd, d), f32(1, d), f32(1, dq), f32(nk, s, hd), f32(nk, s, hd), i32()
        )
    )
    arts["dev_moe_norm"] = to_hlo_text_untupled(
        jax.jit(M.moe_norm_step).lower(f32(d), f32(1, d))
    )
    arts["dev_router"] = to_hlo_text_untupled(
        jax.jit(M.router_step).lower(f32(d, e), f32(1, d))
    )
    arts["dev_residual"] = to_hlo_text_untupled(
        jax.jit(M.residual_add_step).lower(f32(1, d), f32(1, d))
    )
    # Direct-args expert path, untupled (same math as experts_direct_*).
    for ns in (k, NUM_SLOTS):
        wspecs = []
        for _ in range(ns):
            wspecs += [f32(d, cfg.d_ffn), f32(d, cfg.d_ffn), f32(cfg.d_ffn, d)]
        arts[f"dev_experts_ns{ns}"] = to_hlo_text_untupled(
            jax.jit(M.experts_forward_direct).lower(f32(1, d), f32(ns), *wspecs)
        )
    arts["dev_lm_head"] = to_hlo_text_untupled(
        jax.jit(M.lm_head_step).lower(f32(d), f32(d, v), f32(1, d))
    )
    return arts


# Bucket sizes of the batched decode family (`dev_b{B}_*`): the live
# scheduler packs its active requests into the smallest bucket that
# fits, so concurrent requests share one forward pass per iteration
# (continuous batching). B = 1 is the plain `dev_*` family.
BATCH_BUCKETS = (2, 4, 8)


def lower_batched_artifacts(cfg=CFG):
    """Return {name: hlo_text} for the ``dev_b{B}_*`` batched roles.

    Every artifact is untupled (single array root) like the `dev_*`
    family, lowered once per bucket size in `BATCH_BUCKETS`. Roles whose
    math is row-wise reuse the batch-1 functions at [B, ...] shapes; the
    appends/attention/router/experts use the dedicated batched
    formulations in `model.py` (per-slot cache banks stay SEPARATE
    [Hkv, S, hd] buffers — the same shape the batch-1 `DeviceState`
    owns — so a request keeps its cache across bucket up/downshifts and
    the batched attention takes them as 2B direct arguments).
    """
    d, dq, e, k = cfg.d_embed, cfg.d_qkv, cfg.n_experts, cfg.top_k
    nh, nk, hd, s, v = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim, cfg.max_seq, cfg.vocab
    arts = {}
    for bsz in BATCH_BUCKETS:
        p = f"dev_b{bsz}_"
        arts[p + "embed"] = to_hlo_text_untupled(
            jax.jit(M.embed_step).lower(f32(v, d), i32(bsz))
        )
        arts[p + "qkv"] = to_hlo_text_untupled(
            jax.jit(M.qkv_step).lower(f32(d), f32(d, dq), f32(bsz, d))
        )
        arts[p + "k_append"] = to_hlo_text_untupled(
            jax.jit(M.batched_k_append_step).lower(
                f32(nk, s, hd), f32(bsz, dq), i32(bsz), i32()
            )
        )
        arts[p + "v_append"] = to_hlo_text_untupled(
            jax.jit(M.batched_v_append_step).lower(
                f32(nk, s, hd), f32(bsz, dq), i32(bsz), i32()
            )
        )
        cache_specs = [f32(nk, s, hd)] * (2 * bsz)
        arts[p + "attn_out"] = to_hlo_text_untupled(
            jax.jit(M.batched_attn_out_step).lower(
                f32(nh * hd, d), f32(bsz, d), f32(bsz, dq), i32(bsz), *cache_specs
            )
        )
        arts[p + "moe_norm"] = to_hlo_text_untupled(
            jax.jit(M.moe_norm_step).lower(f32(d), f32(bsz, d))
        )
        arts[p + "router"] = to_hlo_text_untupled(
            jax.jit(M.batched_router_step).lower(f32(d, e), f32(bsz, d))
        )
        # Rows route to different experts, so the batched expert role
        # gathers per-row slots from the node's stacked residents — one
        # variant per (resident count, slot count) like the fast family.
        for el in (8, 16):
            for ns in (k, NUM_SLOTS):
                arts[p + f"experts_el{el}_ns{ns}"] = to_hlo_text_untupled(
                    jax.jit(M.batched_experts_forward).lower(
                        f32(el, d, cfg.d_ffn), f32(el, d, cfg.d_ffn),
                        f32(el, cfg.d_ffn, d),
                        f32(bsz, d), i32(bsz, ns), f32(bsz, ns),
                    )
                )
        # Dedup variant: when the bucket's rows route to <= ns DISTINCT
        # experts on this node, each distinct expert runs once over the
        # whole batch instead of once per (row, slot) weight gather.
        for el in (8, 16):
            for ns in (k, NUM_SLOTS):
                arts[p + f"experts_dedup_el{el}_ns{ns}"] = to_hlo_text_untupled(
                    jax.jit(M.batched_experts_dedup).lower(
                        f32(el, d, cfg.d_ffn), f32(el, d, cfg.d_ffn),
                        f32(el, cfg.d_ffn, d),
                        f32(bsz, d), i32(ns), i32(bsz, ns), f32(bsz, ns),
                    )
                )
        arts[p + "residual"] = to_hlo_text_untupled(
            jax.jit(M.residual_add_step).lower(f32(bsz, d), f32(bsz, d))
        )
        arts[p + "lm_head"] = to_hlo_text_untupled(
            jax.jit(M.lm_head_step).lower(f32(d), f32(d, v), f32(bsz, d))
        )
    return arts


# Chunk sizes of the prefill family (`dev_p{T}_*`): the live scheduler
# evaluates T consecutive prompt positions of one request per dispatch
# (ragged tails pad the T=8 chunk). Kept in sync with the rust mirror
# (`runtime::prefill::PREFILL_CHUNKS`) through the manifest's
# `prefill_chunk_max` (the chunks are the powers of 4 from 8 up to it).
PREFILL_CHUNKS = (8, 32)


def lower_prefill_artifacts(cfg=CFG):
    """Return {name: hlo_text} for the ``dev_p{T}_*`` chunked prefill
    roles (untupled, like every other `dev_*` family).

    Per chunk size T in `PREFILL_CHUNKS`: the row-wise roles
    (embed/qkv/moe_norm/residual) and the per-row router/experts are the
    batch roles lowered again at leading dim T; the K/V appends write
    all T rows at pos..pos+T in one dynamic-update-slice into the SAME
    `[Hkv, S, hd]` per-request cache the decode families use, and the
    attention role applies a causal mask over the chunk. No lm_head
    variant exists — prompt positions never produce logits (the last
    prompt token runs on the decode path, which samples).
    """
    d, dq, e, k = cfg.d_embed, cfg.d_qkv, cfg.n_experts, cfg.top_k
    nh, nk, hd, s, v = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim, cfg.max_seq, cfg.vocab
    arts = {}
    for t in PREFILL_CHUNKS:
        p = f"dev_p{t}_"
        arts[p + "embed"] = to_hlo_text_untupled(
            jax.jit(M.embed_step).lower(f32(v, d), i32(t))
        )
        arts[p + "qkv"] = to_hlo_text_untupled(
            jax.jit(M.qkv_step).lower(f32(d), f32(d, dq), f32(t, d))
        )
        arts[p + "k_append"] = to_hlo_text_untupled(
            jax.jit(M.prefill_k_append_step).lower(f32(nk, s, hd), f32(t, dq), i32())
        )
        arts[p + "v_append"] = to_hlo_text_untupled(
            jax.jit(M.prefill_v_append_step).lower(f32(nk, s, hd), f32(t, dq), i32())
        )
        arts[p + "attn_out"] = to_hlo_text_untupled(
            jax.jit(M.prefill_attn_out_step).lower(
                f32(nh * hd, d), f32(t, d), f32(t, dq), f32(nk, s, hd),
                f32(nk, s, hd), i32(),
            )
        )
        arts[p + "moe_norm"] = to_hlo_text_untupled(
            jax.jit(M.moe_norm_step).lower(f32(d), f32(t, d))
        )
        arts[p + "router"] = to_hlo_text_untupled(
            jax.jit(M.batched_router_step).lower(f32(d, e), f32(t, d))
        )
        # Chunk rows route independently like batch rows, so the expert
        # role is the gathered batched formulation at leading dim T —
        # one variant per (resident count, slot count).
        for el in (8, 16):
            for ns in (k, NUM_SLOTS):
                arts[p + f"experts_el{el}_ns{ns}"] = to_hlo_text_untupled(
                    jax.jit(M.batched_experts_forward).lower(
                        f32(el, d, cfg.d_ffn), f32(el, d, cfg.d_ffn),
                        f32(el, cfg.d_ffn, d),
                        f32(t, d), i32(t, ns), f32(t, ns),
                    )
                )
        arts[p + "residual"] = to_hlo_text_untupled(
            jax.jit(M.residual_add_step).lower(f32(t, d), f32(t, d))
        )
    return arts


def lower_sampler_artifacts(cfg=CFG):
    """Return {name: hlo_text} for the on-device sampler roles.

    Three untupled roles per batch width — greedy argmax, seeded top-k
    softmax sampling, stop membership — at B = 1 (`dev_sample_*`,
    chained off `dev_lm_head`) and every bucket in `BATCH_BUCKETS`
    (`dev_b{B}_sample_*`, chained off `dev_b{B}_lm_head`). With these,
    a decode iteration downloads the [B, 2] packed (token, logprob) and
    the [B] stop mask instead of the [B, V] logits.
    """
    v = cfg.vocab
    arts = {}
    for bsz in (1,) + BATCH_BUCKETS:
        p = "dev_sample_" if bsz == 1 else f"dev_b{bsz}_sample_"
        arts[p + "greedy"] = to_hlo_text_untupled(
            jax.jit(M.sample_greedy_step).lower(f32(bsz, v))
        )
        arts[p + "topk"] = to_hlo_text_untupled(
            jax.jit(M.sample_topk_step).lower(
                f32(bsz, v), i32(bsz), f32(bsz), i32(bsz), i32(bsz), i32(bsz)
            )
        )
        arts[p + "stop"] = to_hlo_text_untupled(
            jax.jit(M.sample_stop_step).lower(f32(bsz, 2), f32(bsz, M.SAMPLER_MAX_STOP))
        )
    return arts


def write_manifest(path, cfg=CFG):
    with open(path, "w") as fh:
        fh.write("# dbrx-nano artifact manifest (parsed by rust/src/runtime)\n")
        for kk, vv in [
            ("n_layers", cfg.n_layers),
            ("d_embed", cfg.d_embed),
            ("d_ffn", cfg.d_ffn),
            ("n_experts", cfg.n_experts),
            ("top_k", cfg.top_k),
            ("n_heads", cfg.n_heads),
            ("n_kv_heads", cfg.n_kv_heads),
            ("head_dim", cfg.head_dim),
            ("vocab", cfg.vocab),
            ("max_seq", cfg.max_seq),
            ("num_slots", NUM_SLOTS),
            ("fast_num_slots", cfg.top_k),
            # The untupled dev_* artifact set is present (device-resident
            # decode path; rust falls back to the host path when 0/absent).
            ("device_artifacts", 1),
            # Largest bucket of the batched `dev_b{B}_*` decode family
            # (buckets are the powers of two from 2 up to this value;
            # 0/absent = no batched artifacts, serial decode only).
            ("max_batch", max(BATCH_BUCKETS)),
            # On-device sampler roles (`dev_sample_*` / `dev_b{B}_sample_*`)
            # are present; 0/absent = host sampling only. The max_top_k /
            # max_stop values are the artifacts' static operand widths.
            ("sampler_artifacts", 1),
            ("sampler_max_top_k", M.SAMPLER_MAX_TOP_K),
            ("sampler_max_stop", M.SAMPLER_MAX_STOP),
            # Dedup expert roles (`dev_b{B}_experts_dedup_el{el}_ns{ns}`)
            # are present; 0/absent = gathered batched experts only.
            ("dedup_artifacts", 1),
            # Largest chunk of the `dev_p{T}_*` chunked prefill family
            # (chunks are the powers of 4 from 8 up to this value, so
            # 32 → T ∈ {8, 32}; 0/absent = serial prefill only).
            ("prefill_chunk_max", max(PREFILL_CHUNKS)),
        ]:
            fh.write(f"{kk} = {vv}\n")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument(
        "--donate-caches",
        action="store_true",
        help="add input/output aliasing hints on dev_{k,v}_append "
        "(see lower_device_artifacts; off by default)",
    )
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    arts = lower_artifacts()
    arts.update(lower_device_artifacts(donate_caches=args.donate_caches))
    arts.update(lower_batched_artifacts())
    arts.update(lower_prefill_artifacts())
    arts.update(lower_sampler_artifacts())
    for name, text in arts.items():
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as fh:
            fh.write(text)
        print(f"wrote {path} ({len(text)} chars)")

    params = M.init_params(CFG, seed=args.seed)
    npz_path = os.path.join(args.out_dir, "weights.npz")
    np.savez(npz_path, **{kk: np.asarray(vv) for kk, vv in params.items()})
    print(f"wrote {npz_path} ({os.path.getsize(npz_path)} bytes)")

    write_manifest(os.path.join(args.out_dir, "manifest.txt"))
    print("wrote manifest.txt")


if __name__ == "__main__":
    main()
