//! Expert→node assignment and per-layer execution planning — the three
//! strategies of §4.2 plus the replica-aware placement §5.3 relies on.
//!
//! For each decoder layer the `Planner` turns a `RouterDraw` into a
//! `LayerPlan`: which experts run on which node, which of those runs are
//! router-selected (their outputs enter the weighted sum) and which are
//! padding (busy-full extras / LRU keep-warm runs whose outputs are
//! zeroed out).

use crate::config::Balancing;
use crate::model::layout::ExpertLayout;
use crate::moe::lru::LruTracker;
use crate::moe::router::RouterDraw;

/// One expert execution on a node.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ExpertRun {
    pub expert: usize,
    /// Router weight if selected; padding runs carry weight 0 and are
    /// zeroed in the combine (§4.2 busy-full / LRU keep-warm).
    pub weight: f32,
    pub is_padding: bool,
}

/// Work assigned to one node for one layer.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct NodeWork {
    pub runs: Vec<ExpertRun>,
}

impl NodeWork {
    pub fn selected_count(&self) -> usize {
        self.runs.iter().filter(|r| !r.is_padding).count()
    }

    pub fn total_count(&self) -> usize {
        self.runs.len()
    }
}

/// The cluster-wide plan for one layer.
#[derive(Debug, Clone, PartialEq)]
pub struct LayerPlan {
    pub per_node: Vec<NodeWork>,
    /// max over nodes of *selected* counts — the quota every node is
    /// padded up to under router-aided loading.
    pub max_selected: usize,
}

impl LayerPlan {
    /// Experts executed on the busiest node (the fork-join critical path).
    pub fn max_executed(&self) -> usize {
        self.per_node.iter().map(NodeWork::total_count).max().unwrap_or(0)
    }

    /// Mean executed experts per node (Table 1's E[#exec experts]).
    pub fn mean_executed(&self) -> f64 {
        if self.per_node.is_empty() {
            return 0.0;
        }
        self.per_node.iter().map(NodeWork::total_count).sum::<usize>() as f64
            / self.per_node.len() as f64
    }

    /// Invariants checked by property tests.
    pub fn check(&self, draw: &RouterDraw, layout: &ExpertLayout) -> Result<(), String> {
        // 1. Every selected expert runs exactly once with its weight.
        for (i, &e) in draw.selected.iter().enumerate() {
            let runs: Vec<(usize, &ExpertRun)> = self
                .per_node
                .iter()
                .enumerate()
                .flat_map(|(n, w)| w.runs.iter().map(move |r| (n, r)))
                .filter(|(_, r)| r.expert == e && !r.is_padding)
                .collect();
            if runs.len() != 1 {
                return Err(format!("expert {e} selected-run count {}", runs.len()));
            }
            let (node, run) = runs[0];
            if !layout.resident[node].contains(&e) {
                return Err(format!("expert {e} run on non-holder node {node}"));
            }
            if (run.weight - draw.weights[i]).abs() > 1e-6 {
                return Err(format!("expert {e} weight mismatch"));
            }
        }
        // 2. Padding runs are resident and weight-0.
        for (n, w) in self.per_node.iter().enumerate() {
            for r in &w.runs {
                if r.is_padding {
                    if r.weight != 0.0 {
                        return Err("padding run with nonzero weight".into());
                    }
                    if !layout.resident[n].contains(&r.expert) {
                        return Err(format!(
                            "padding expert {} not resident on node {n}",
                            r.expert
                        ));
                    }
                }
            }
            // 3. No expert runs twice on the same node.
            let mut ids: Vec<usize> = w.runs.iter().map(|r| r.expert).collect();
            ids.sort_unstable();
            let before = ids.len();
            ids.dedup();
            if ids.len() != before {
                return Err(format!("node {n} runs an expert twice"));
            }
        }
        Ok(())
    }
}

/// Stateful planner: owns per-node LRU trackers (router-aided loading
/// needs them across layers/tokens).
#[derive(Debug, Clone)]
pub struct Planner {
    pub balancing: Balancing,
    pub layout: ExpertLayout,
    lru: Vec<LruTracker>,
}

impl Planner {
    pub fn new(balancing: Balancing, layout: ExpertLayout) -> Planner {
        let lru = layout.resident.iter().map(|r| LruTracker::new(r)).collect();
        Planner { balancing, layout, lru }
    }

    pub fn lru(&self, node: usize) -> &LruTracker {
        &self.lru[node]
    }

    /// Plan one layer.
    pub fn plan_layer(&mut self, draw: &RouterDraw) -> LayerPlan {
        let n_nodes = self.layout.n_nodes;
        let mut per_node: Vec<NodeWork> = vec![NodeWork::default(); n_nodes];

        // Assign each selected expert to the least-loaded holder node
        // (replica-aware: with overlapped placement this is the §5.3
        // rebalancing; with disjoint placement it degenerates to "the
        // owner").
        for (i, &e) in draw.selected.iter().enumerate() {
            let node = *self.layout.holders[e]
                .iter()
                .min_by_key(|&&n| (per_node[n].runs.len(), n))
                .expect("expert with no holder");
            per_node[node].runs.push(ExpertRun {
                expert: e,
                weight: draw.weights[i],
                is_padding: false,
            });
        }
        let max_selected = per_node.iter().map(NodeWork::selected_count).max().unwrap_or(0);

        match self.balancing {
            Balancing::SelectedOnly => {}
            Balancing::BusyFull => {
                // Every resident expert runs every layer; unselected ones
                // are zeroed in the weighted sum (§4.2).
                for n in 0..n_nodes {
                    let already: Vec<usize> =
                        per_node[n].runs.iter().map(|r| r.expert).collect();
                    for &e in &self.layout.resident[n] {
                        if !already.contains(&e) {
                            per_node[n].runs.push(ExpertRun {
                                expert: e,
                                weight: 0.0,
                                is_padding: true,
                            });
                        }
                    }
                }
            }
            Balancing::RouterAided => {
                // Pad every node up to `max_selected` with LRU experts.
                for n in 0..n_nodes {
                    let have = per_node[n].runs.len();
                    if have < max_selected {
                        let exclude: Vec<usize> =
                            per_node[n].runs.iter().map(|r| r.expert).collect();
                        for e in self.lru[n].least_recent(max_selected - have, &exclude) {
                            per_node[n].runs.push(ExpertRun {
                                expert: e,
                                weight: 0.0,
                                is_padding: true,
                            });
                        }
                    }
                }
            }
        }

        // Record usage for LRU bookkeeping.
        for (n, w) in per_node.iter().enumerate() {
            let ids: Vec<usize> = w.runs.iter().map(|r| r.expert).collect();
            self.lru[n].touch_all(&ids);
        }

        LayerPlan { per_node, max_selected }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Balancing, ClusterConfig, ModelDims, Strategy};
    use crate::moe::router::SyntheticRouter;

    fn layout(n_nodes: usize, cap: usize) -> ExpertLayout {
        let mut c = ClusterConfig::new(n_nodes, Strategy::PLrD);
        c.experts_per_node_cap = cap;
        ExpertLayout::build(&c, &ModelDims::dbrx_132b())
    }

    #[test]
    fn selected_only_runs_exactly_topk() {
        let l = layout(2, 8);
        let mut p = Planner::new(Balancing::SelectedOnly, l.clone());
        let mut r = SyntheticRouter::new(16, 4, 7);
        for _ in 0..200 {
            let d = r.draw();
            let plan = p.plan_layer(&d);
            plan.check(&d, &l).unwrap();
            let total: usize = plan.per_node.iter().map(|w| w.total_count()).sum();
            assert_eq!(total, 4);
        }
    }

    #[test]
    fn busy_full_runs_all_resident() {
        let l = layout(2, 8);
        let mut p = Planner::new(Balancing::BusyFull, l.clone());
        let mut r = SyntheticRouter::new(16, 4, 8);
        let d = r.draw();
        let plan = p.plan_layer(&d);
        plan.check(&d, &l).unwrap();
        for (n, w) in plan.per_node.iter().enumerate() {
            assert_eq!(w.total_count(), l.resident[n].len(), "node {n}");
        }
        // §4.2: "only 4 of the 16 computations spent are necessary".
        let padding: usize = plan
            .per_node
            .iter()
            .flat_map(|w| &w.runs)
            .filter(|r| r.is_padding)
            .count();
        assert_eq!(padding, 12);
    }

    #[test]
    fn router_aided_pads_to_max_selected() {
        let l = layout(2, 8);
        let mut p = Planner::new(Balancing::RouterAided, l.clone());
        let mut r = SyntheticRouter::new(16, 4, 9);
        for _ in 0..200 {
            let d = r.draw();
            let plan = p.plan_layer(&d);
            plan.check(&d, &l).unwrap();
            for w in &plan.per_node {
                assert_eq!(w.total_count(), plan.max_selected);
            }
        }
    }

    #[test]
    fn router_aided_two_node_mean_load_near_2_65() {
        // Table 1: E[#exec experts/node/layer] = 2.65 on two nodes.
        let l = layout(2, 8);
        let mut p = Planner::new(Balancing::RouterAided, l);
        let mut r = SyntheticRouter::new(16, 4, 10);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            sum += p.plan_layer(&r.draw()).mean_executed();
        }
        let mean = sum / n as f64;
        assert!((mean - 2.65).abs() < 0.05, "E[exec] = {mean}");
    }

    #[test]
    fn router_aided_four_node_overlap_reduces_load() {
        // Table 1: 1.57 on four nodes — the overlapped placement (8
        // resident per node, replication 2) lets selected experts move to
        // less-loaded replicas.
        let l = layout(4, 8);
        let mut p = Planner::new(Balancing::RouterAided, l);
        let mut r = SyntheticRouter::new(16, 4, 11);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            sum += p.plan_layer(&r.draw()).mean_executed();
        }
        let mean = sum / n as f64;
        // Strict partition would give ≈1.97; replication must beat it.
        assert!(
            mean < 1.75 && mean > 1.2,
            "E[exec] = {mean} (paper: 1.57)"
        );
    }

    #[test]
    fn three_node_overlap_load() {
        // Table 1: 2.32 on three nodes (replication 1.5).
        let l = layout(3, 8);
        let mut p = Planner::new(Balancing::RouterAided, l);
        let mut r = SyntheticRouter::new(16, 4, 12);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            sum += p.plan_layer(&r.draw()).mean_executed();
        }
        let mean = sum / n as f64;
        assert!(
            (1.8..2.6).contains(&mean),
            "E[exec] = {mean} (paper: 2.32)"
        );
    }

    #[test]
    fn lru_padding_keeps_all_experts_fresh() {
        // §4.2: "our LRU mechanism ensures that each expert performs
        // calculations in time" — over a token's 40 layers every resident
        // expert must be touched at least once on a 2-node cluster.
        let l = layout(2, 8);
        let mut p = Planner::new(Balancing::RouterAided, l.clone());
        let mut r = SyntheticRouter::new(16, 4, 13);
        for _token in 0..5 {
            for _layer in 0..40 {
                p.plan_layer(&r.draw());
            }
            for n in 0..2 {
                for &e in &l.resident[n] {
                    let s = p.lru(n).staleness(e).unwrap();
                    // Rough bound: a full rotation of 8 residents at ≥2
                    // touches/layer is ≤ 4 layers ≈ 12 touches.
                    assert!(s < 40, "expert {e} stale for {s} touches on node {n}");
                }
            }
        }
    }

    #[test]
    fn prop_plan_invariants_all_strategies() {
        crate::util::prop::forall("plan invariants", 96, |g| {
            let n_nodes = 1 + g.usize_in(0..4);
            let cap = 4 + g.usize_in(0..12);
            let balancing = match g.usize_in(0..3) {
                0 => Balancing::SelectedOnly,
                1 => Balancing::BusyFull,
                _ => Balancing::RouterAided,
            };
            let l = layout(n_nodes, cap);
            let mut p = Planner::new(balancing, l.clone());
            let mut r = SyntheticRouter::new(16, 4, g.u64_in(0..1 << 30));
            (0..20).all(|_| {
                let d = r.draw();
                let plan = p.plan_layer(&d);
                plan.check(&d, &l).is_ok()
            })
        });
    }
}
