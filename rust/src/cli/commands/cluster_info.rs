//! `apple-moe cluster-info` — model arithmetic (Table 1 rows (a)–(e)),
//! memory budget, and the expert placement for a cluster size.

use anyhow::Result;

use crate::cli::args::Args;
use crate::config::{ClusterConfig, ModelDims, Strategy};
use crate::model::counts::ModelCounts;
use crate::model::layout::ExpertLayout;
use crate::util::fmt::{format_bytes, render_table};

pub fn run(args: &mut Args) -> Result<()> {
    let nodes = args.usize_or("nodes", 2)?;
    let model_name = args.str_or("model", "dbrx-132b");
    args.finish()?;
    let model = ModelDims::by_name(&model_name)
        .ok_or_else(|| anyhow::anyhow!("unknown model '{model_name}'"))?;
    let c = ModelCounts::of(&model);

    println!("# {} — derived quantities (paper Table 1)\n", model.name);
    let rows = vec![
        vec!["quantity".into(), "value".into()],
        vec!["#Layers".into(), model.n_layers.to_string()],
        vec![
            "D_embed / D_qkv / D_ffn".into(),
            format!("{} / {} / {}", model.d_embed, model.d_qkv_hidden, model.d_ffn),
        ],
        vec![
            "experts (top-k)".into(),
            format!("{} (top-{})", model.n_experts, model.top_k),
        ],
        vec!["comm data / token (a)".into(), format_bytes(c.comm_bytes)],
        vec!["#Params_SA bytes (b)".into(), format_bytes(c.sa_param_bytes)],
        vec!["#FLOPs_SA (c)".into(), format!("{:.1}e9", c.sa_flops / 1e9)],
        vec![
            "#Params/expert bytes (d)".into(),
            format_bytes(c.expert_param_bytes),
        ],
        vec![
            "#FLOPs/expert (e)".into(),
            format!("{:.1}e9", c.expert_flops / 1e9),
        ],
        vec!["total params".into(), format!("{:.1}B", c.total_params(&model) as f64 / 1e9)],
        vec!["total bytes".into(), format_bytes(c.total_bytes(&model))],
    ];
    print!("{}", render_table(&rows));

    let cluster = ClusterConfig::new(nodes, Strategy::PLrD);
    let budget = ExpertLayout::budget_experts_per_node(&cluster, &model);
    let layout = ExpertLayout::build(&cluster, &model);
    let (rmin, rmean, rmax) = layout.replication();
    println!(
        "\n# placement on {nodes} node(s): budget {budget} experts/node, replication min/mean/max = {rmin}/{rmean:.2}/{rmax}"
    );
    for (n, res) in layout.resident.iter().enumerate() {
        println!("  node {n}: experts {res:?}");
    }
    Ok(())
}
