//! Integration: the live threaded cluster (decentralized P-L_R-D wire
//! protocol AND centralized Figs. 2–3 protocol) generates exactly the
//! same tokens as the dense single-node engine — the correctness claim
//! behind Table 3's comparisons — now through the streaming serving
//! API: tokens observed event-by-event must equal the joined result,
//! concurrent (iteration-level interleaved) serving must be
//! token-identical to serial serving, and cancellation must free a
//! request's decode state without disturbing the others.

// Test code: a panic is the failure report (see clippy.toml).
#![allow(clippy::unwrap_used)]

use std::path::{Path, PathBuf};

use apple_moe::cluster::live::{LiveCluster, LiveConfig};
use apple_moe::config::{Balancing, Topology};
use apple_moe::engine::request::RequestResult;
use apple_moe::engine::scheduler::SchedPolicy;
use apple_moe::engine::{DenseEngine, FinishReason, Request, Sampler, TokenEvent};

fn artifacts_dir() -> Option<PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.txt").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: run `make artifacts` first");
        None
    }
}

fn dense_tokens(dir: &Path, req: &Request) -> Vec<u32> {
    let engine = DenseEngine::load(dir).unwrap();
    engine.submit(req.clone()).unwrap().join().unwrap().generated
}

/// Blocking single-request serve on the streaming API (inactivity-
/// bounded so a wedged cluster fails the test instead of hanging it).
fn serve_one(cluster: &LiveCluster, req: &Request) -> RequestResult {
    cluster
        .submit(req.clone())
        .unwrap()
        .join_timeout(std::time::Duration::from_secs(600))
        .unwrap()
}

#[test]
fn decentralized_two_nodes_matches_dense() {
    let Some(dir) = artifacts_dir() else { return };
    let req = Request::new(1, vec![3, 141, 59, 26], 12);
    let want = dense_tokens(&dir, &req);
    assert_eq!(want.len(), 12);

    let cfg = LiveConfig::new(dir.clone(), 2);
    let cluster = LiveCluster::start(cfg).unwrap();
    let res = serve_one(&cluster, &req);
    cluster.shutdown();
    assert_eq!(res.generated, want, "distributed generation diverged");
    assert_eq!(res.metrics.decode.tokens, 12);
    assert_eq!(res.finish, FinishReason::Length);
    // The all-reduce path must actually have been exercised.
    assert!(res.metrics.decode.breakdown_secs().1 > 0.0, "no comm time?");
    // Serving-surface timing is metered on real hardware now.
    assert!(res.metrics.ttft_ns > 0, "ttft not metered");
    assert!(res.metrics.latency_ns >= res.metrics.ttft_ns);
}

#[test]
fn centralized_two_nodes_matches_dense() {
    let Some(dir) = artifacts_dir() else { return };
    let req = Request::new(2, vec![10, 20, 30], 8);
    let want = dense_tokens(&dir, &req);

    let mut cfg = LiveConfig::new(dir.clone(), 2);
    cfg.topology = Topology::Centralized;
    cfg.balancing = Balancing::SelectedOnly;
    let cluster = LiveCluster::start(cfg).unwrap();
    let res = serve_one(&cluster, &req);
    cluster.shutdown();
    assert_eq!(res.generated, want, "centralized generation diverged");
}

#[test]
fn busy_full_loading_matches_dense() {
    // P-L_B runs every expert every layer with zeroed padding — numerics
    // must be unchanged (§4.2).
    let Some(dir) = artifacts_dir() else { return };
    let req = Request::new(3, vec![100, 200], 6);
    let want = dense_tokens(&dir, &req);

    let mut cfg = LiveConfig::new(dir.clone(), 2);
    cfg.balancing = Balancing::BusyFull;
    let cluster = LiveCluster::start(cfg).unwrap();
    let res = serve_one(&cluster, &req);
    cluster.shutdown();
    assert_eq!(res.generated, want, "busy-full generation diverged");
}

#[test]
fn single_node_cluster_works() {
    let Some(dir) = artifacts_dir() else { return };
    let req = Request::new(4, vec![42], 5);
    let want = dense_tokens(&dir, &req);
    let cluster = LiveCluster::start(LiveConfig::new(dir.clone(), 1)).unwrap();
    let res = serve_one(&cluster, &req);
    cluster.shutdown();
    assert_eq!(res.generated, want);
}

/// Serve `req` on a cluster forced to the given decode path.
fn serve_on_path(
    dir: &Path,
    nodes: usize,
    topology: Topology,
    device_resident: bool,
    req: &Request,
) -> RequestResult {
    let mut cfg = LiveConfig::new(dir.to_path_buf(), nodes);
    cfg.topology = topology;
    if topology == Topology::Centralized {
        cfg.balancing = Balancing::SelectedOnly;
    }
    cfg.device_resident = device_resident;
    let cluster = LiveCluster::start(cfg).unwrap();
    let res = serve_one(&cluster, req);
    cluster.shutdown();
    res
}

/// The §Perf acceptance: for both topologies and 1/2 nodes, the
/// device-resident decode loop generates the same tokens as the
/// host-roundtrip reference loop — while performing ZERO per-layer K/V
/// cache host crossings (the per-token transfer counters stay under one
/// cache's size; the reference path moves every cache twice per layer).
#[test]
fn device_resident_cluster_matches_host_path() {
    let Some(dir) = artifacts_dir() else { return };
    let manifest = apple_moe::runtime::Manifest::load(&dir).unwrap();
    if !manifest.device_artifacts {
        eprintln!("skipping: artifacts predate the dev_* set");
        return;
    }
    let req = Request::new(10, vec![3, 141, 59], 8);
    // One full generation of K/V caches (all layers, one direction).
    let caches_bytes = (manifest.n_kv_heads
        * manifest.max_seq
        * manifest.head_dim
        * 4
        * manifest.n_layers) as f64;

    for topology in [Topology::Decentralized, Topology::Centralized] {
        for nodes in [1usize, 2] {
            let host = serve_on_path(&dir, nodes, topology, false, &req);
            let dev = serve_on_path(&dir, nodes, topology, true, &req);
            assert_eq!(
                dev.generated, host.generated,
                "tokens diverge: {topology:?} x {nodes} nodes"
            );
            // Decode-phase transfer accounting: the host path
            // round-trips all caches every token; the device path must
            // stay under a quarter of ONE cache generation per token.
            let host_bpt = host.metrics.decode.transfer_bytes_per_token();
            let dev_bpt = dev.metrics.decode.transfer_bytes_per_token();
            assert!(
                host_bpt > caches_bytes,
                "host path moved {host_bpt} B/token — meter broken? ({topology:?} x {nodes})"
            );
            assert!(
                dev_bpt < caches_bytes / 4.0,
                "device path moved {dev_bpt} B/token ({topology:?} x {nodes})"
            );
            assert!(
                dev_bpt < host_bpt / 10.0,
                "device path should move >=10x fewer bytes: {dev_bpt} vs {host_bpt}"
            );
        }
    }
}

#[test]
fn multiple_requests_reuse_cluster() {
    let Some(dir) = artifacts_dir() else { return };
    let cluster = LiveCluster::start(LiveConfig::new(dir.clone(), 2)).unwrap();
    let r1 = serve_one(&cluster, &Request::new(5, vec![1, 2, 3], 4));
    let r2 = serve_one(&cluster, &Request::new(6, vec![9, 9], 4));
    cluster.shutdown();
    assert_eq!(r1.generated.len(), 4);
    assert_eq!(r2.generated.len(), 4);
    // Same prompts must reproduce across a fresh cluster (KV state and
    // sampler reset per request).
    let cluster2 = LiveCluster::start(LiveConfig::new(dir, 2)).unwrap();
    let r1b = serve_one(&cluster2, &Request::new(7, vec![1, 2, 3], 4));
    cluster2.shutdown();
    assert_eq!(r1.generated, r1b.generated);
}

/// Streaming equivalence (satellite): tokens observed event-by-event
/// via `TokenEvent::Token` are identical to `join()`'s
/// `RequestResult.generated`, on both the dense engine and the live
/// cluster; `Started` precedes the first token and carries the TTFT.
#[test]
fn streamed_tokens_match_joined_result() {
    let Some(dir) = artifacts_dir() else { return };
    let req = Request::new(11, vec![7, 77, 177], 6);
    let want = dense_tokens(&dir, &req);

    // Dense engine: drain the stream by hand.
    let engine = DenseEngine::load(&dir).unwrap();
    let handle = engine.submit(req.clone()).unwrap();
    let (streamed, result) = drain(&handle);
    assert_eq!(streamed, result.generated, "dense stream != joined result");
    assert_eq!(result.generated, want);

    // Live 2-node cluster: same contract over the fabric.
    let cluster = LiveCluster::start(LiveConfig::new(dir, 2)).unwrap();
    let handle = cluster.submit(req).unwrap();
    let (streamed, result) = drain(&handle);
    cluster.shutdown();
    assert_eq!(streamed, result.generated, "live stream != joined result");
    assert_eq!(result.generated, want);
}

/// Collect (streamed token ids, final result) from a handle, asserting
/// event-order invariants along the way.
fn drain(handle: &apple_moe::engine::RequestHandle) -> (Vec<u32>, RequestResult) {
    let mut streamed = Vec::new();
    let mut started = false;
    loop {
        match handle.next_event().expect("stream ended without terminal event") {
            TokenEvent::Started { ttft_s, .. } => {
                assert!(!started, "Started emitted twice");
                assert!(streamed.is_empty(), "Started must precede the first token");
                assert!(ttft_s > 0.0);
                started = true;
            }
            TokenEvent::Token { id, logprob } => {
                assert!(started, "Token before Started");
                assert!(logprob.is_some(), "live engines report logprobs");
                streamed.push(id);
            }
            TokenEvent::Done { result } => {
                assert!(started || result.generated.is_empty());
                return (streamed, result);
            }
            TokenEvent::Failed { error, .. } => panic!("request failed: {error}"),
        }
    }
}

/// The acceptance criterion: ≥2 interleaved requests on the live
/// cluster, round-robin at iteration level, token-identical per request
/// to serial serving — on both topologies — with queueing metered for
/// the request that waits for admission.
#[test]
fn concurrent_round_robin_matches_serial() {
    let Some(dir) = artifacts_dir() else { return };
    let reqs = [
        Request::new(20, vec![3, 141, 59, 26], 6),
        Request::new(21, vec![10, 20, 30], 6),
        Request::new(22, vec![100, 200], 5),
    ];

    for topology in [Topology::Decentralized, Topology::Centralized] {
        let mk = |max_active: usize, policy: SchedPolicy| {
            let mut cfg = LiveConfig::new(dir.clone(), 2);
            cfg.topology = topology;
            if topology == Topology::Centralized {
                cfg.balancing = Balancing::SelectedOnly;
            }
            cfg.max_active = max_active;
            cfg.policy = policy;
            LiveCluster::start(cfg).unwrap()
        };

        // Serial reference: one at a time, run to completion.
        let serial = mk(1, SchedPolicy::RunToCompletion);
        let want: Vec<Vec<u32>> =
            reqs.iter().map(|r| serve_one(&serial, r).generated).collect();
        serial.shutdown();

        // Concurrent: submit all three, concurrency 2, round-robin.
        let cluster = mk(2, SchedPolicy::RoundRobin);
        let handles: Vec<_> =
            reqs.iter().map(|r| cluster.submit(r.clone()).unwrap()).collect();
        let results: Vec<RequestResult> =
            handles.into_iter().map(|h| h.join().unwrap()).collect();
        cluster.shutdown();

        for (r, w) in results.iter().zip(&want) {
            assert_eq!(
                &r.generated, w,
                "interleaved tokens diverge from serial ({topology:?}, req {})",
                r.id
            );
        }
        // Interleaving evidence: the second request's first token came
        // out BEFORE the first request finished (round-robin), which
        // serial scheduling cannot do.
        assert!(
            results[1].metrics.ttft_s() < results[0].metrics.latency_s(),
            "no interleaving observed ({topology:?}): ttft[1]={} vs latency[0]={}",
            results[1].metrics.ttft_s(),
            results[0].metrics.latency_s()
        );
        // The third request had to wait for an admission slot: its
        // queueing delay spans at least until the first finisher freed
        // one, so it must exceed request 0's time-to-first-token.
        assert!(
            results[2].metrics.queueing_s() > results[0].metrics.ttft_s(),
            "queueing delay not metered ({topology:?}): queue[2]={} vs ttft[0]={}",
            results[2].metrics.queueing_s(),
            results[0].metrics.ttft_s()
        );
    }
}

fn batched_artifacts(dir: &Path, min_bucket: usize) -> bool {
    let manifest = apple_moe::runtime::Manifest::load(dir).unwrap();
    if manifest.max_batch < min_bucket {
        eprintln!("skipping: artifacts predate the dev_b* batched set");
        return false;
    }
    true
}

/// The continuous-batching acceptance: concurrent requests with MIXED
/// prompt lengths (slots sit at different decode offsets) generate
/// tokens identical to serial batch-1 serving on BOTH topologies at
/// B ∈ {2, 4}, while actually sharing forward passes — batch occupancy
/// well above 1 and strictly fewer executable dispatches per token
/// than serial decode (one batched forward per scheduler iteration,
/// not B serial ones).
#[test]
fn batched_decode_matches_serial_and_amortizes_dispatch() {
    let Some(dir) = artifacts_dir() else { return };
    if !batched_artifacts(&dir, 4) {
        return;
    }
    let reqs = [
        Request::new(60, vec![3, 141, 59, 26], 8),
        Request::new(61, vec![10, 20, 30], 8),
        Request::new(62, vec![100, 200], 8),
        Request::new(63, vec![7, 77, 177, 250, 333], 8),
    ];

    for topology in [Topology::Decentralized, Topology::Centralized] {
        let mk = |max_active: usize| {
            let mut cfg = LiveConfig::new(dir.clone(), 2);
            cfg.topology = topology;
            if topology == Topology::Centralized {
                cfg.balancing = Balancing::SelectedOnly;
            }
            cfg.max_active = max_active;
            LiveCluster::start(cfg).unwrap()
        };

        // Serial reference: one at a time, batch-1 forwards throughout.
        let serial = mk(1);
        let serial_res: Vec<RequestResult> =
            reqs.iter().map(|r| serve_one(&serial, r)).collect();
        serial.shutdown();
        let serial_exec = serial_res[0].metrics.decode.exec_calls_per_token();
        assert!(serial_exec > 0.0, "dispatch counter not metered");
        for r in &serial_res {
            assert!(
                (r.metrics.decode.mean_batch_occupancy() - 1.0).abs() < 1e-9,
                "serial decode must report occupancy 1, got {}",
                r.metrics.decode.mean_batch_occupancy()
            );
        }

        for concurrency in [2usize, 4] {
            let cluster = mk(concurrency);
            let handles: Vec<_> =
                reqs.iter().map(|r| cluster.submit(r.clone()).unwrap()).collect();
            let results: Vec<RequestResult> =
                handles.into_iter().map(|h| h.join().unwrap()).collect();
            cluster.shutdown();

            for (r, w) in results.iter().zip(&serial_res) {
                assert_eq!(
                    r.generated, w.generated,
                    "batched tokens diverge from serial \
                     ({topology:?}, concurrency {concurrency}, req {})",
                    r.id
                );
            }
            // At c4 everything is admitted at once, so every request
            // decodes mostly at full occupancy; at c2 the LAST request
            // ends up decoding its tail alone (its pair finished
            // first), pulling its mean toward ~1.5.
            let min_occ = if concurrency >= 4 { 1.5 } else { 1.2 };
            for r in &results {
                let d = &r.metrics.decode;
                assert!(
                    d.mean_batch_occupancy() > min_occ,
                    "no sharing observed ({topology:?}, c{concurrency}, req {}): \
                     occupancy {}",
                    r.id,
                    d.mean_batch_occupancy()
                );
                // Shared dispatches divide across rows; the tail tokens
                // decoded at lower occupancy dilute the win, so the
                // bound scales with the concurrency.
                let ratio = if concurrency >= 4 { 0.7 } else { 0.9 };
                let max_exec = ratio * serial_exec;
                assert!(
                    d.exec_calls_per_token() < max_exec,
                    "dispatches not amortized ({topology:?}, c{concurrency}, req {}): \
                     {} vs serial {}",
                    r.id,
                    d.exec_calls_per_token(),
                    serial_exec
                );
            }
            // The steady stretch runs at full occupancy: every request
            // saw at least one forward shared by `concurrency` rows.
            for r in &results {
                assert!(
                    r.metrics.decode.occupancy.max() >= concurrency as f64,
                    "bucket never filled ({topology:?}, c{concurrency}, req {}): max {}",
                    r.id,
                    r.metrics.decode.occupancy.max()
                );
            }
        }
    }
}

/// Bucket downshift: with mixed generation budgets at concurrency 4,
/// the batch shrinks as requests complete — the longest request's
/// occupancy spans the full range (4 early, 1 once it decodes alone)
/// while the shortest lives its whole decode at full occupancy. Tokens
/// stay identical to serial throughout the shifts.
#[test]
fn bucket_downshift_as_requests_complete() {
    let Some(dir) = artifacts_dir() else { return };
    if !batched_artifacts(&dir, 4) {
        return;
    }
    let reqs = [
        Request::new(80, vec![3, 141], 4),
        Request::new(81, vec![10, 20], 6),
        Request::new(82, vec![100, 200], 8),
        Request::new(83, vec![7, 77], 16),
    ];
    let want: Vec<Vec<u32>> = reqs.iter().map(|r| dense_tokens(&dir, r)).collect();

    let mut cfg = LiveConfig::new(dir, 2);
    cfg.max_active = 4;
    let cluster = LiveCluster::start(cfg).unwrap();
    let handles: Vec<_> = reqs.iter().map(|r| cluster.submit(r.clone()).unwrap()).collect();
    let results: Vec<RequestResult> =
        handles.into_iter().map(|h| h.join().unwrap()).collect();
    cluster.shutdown();

    for (r, w) in results.iter().zip(&want) {
        assert_eq!(r.generated, w, "tokens diverge across bucket shifts (req {})", r.id);
    }
    let short = &results[0].metrics.decode;
    let long = &results[3].metrics.decode;
    assert!(
        (short.mean_batch_occupancy() - 4.0).abs() < 1e-9,
        "shortest request should decode entirely at occupancy 4, got {}",
        short.mean_batch_occupancy()
    );
    assert!(
        long.occupancy.max() >= 4.0 && long.occupancy.min() <= 1.0,
        "longest request should span occupancy 4 → 1, got {} → {}",
        long.occupancy.max(),
        long.occupancy.min()
    );
    assert!(
        long.mean_batch_occupancy() < short.mean_batch_occupancy(),
        "downshift not reflected in mean occupancy"
    );
}

/// Mid-batch cancellation frees the slot while the batch keeps
/// decoding, and a subsequently submitted request reuses the freed
/// capacity (batching with the survivor) — all token-identical to the
/// uncancelled references.
#[test]
fn mid_batch_cancel_frees_slot_for_reuse() {
    let Some(dir) = artifacts_dir() else { return };
    if !batched_artifacts(&dir, 2) {
        return;
    }
    let long = Request::new(70, vec![3, 141, 59, 26], 64);
    let mid = Request::new(71, vec![10, 20, 30], 24);
    let after = Request::new(72, vec![9, 9, 9], 8);
    let long_want = dense_tokens(&dir, &long);
    let mid_want = dense_tokens(&dir, &mid);
    let after_want = dense_tokens(&dir, &after);

    let mut cfg = LiveConfig::new(dir, 2);
    cfg.max_active = 2;
    let cluster = LiveCluster::start(cfg).unwrap();
    let h_long = cluster.submit(long).unwrap();
    let h_mid = cluster.submit(mid).unwrap();

    // Wait until the long request is demonstrably mid-batch (both
    // requests decoding in shared forwards), then cancel it.
    let mut seen = 0;
    while seen < 2 {
        match h_long.next_event().expect("stream died") {
            TokenEvent::Token { .. } => seen += 1,
            TokenEvent::Done { .. } | TokenEvent::Failed { .. } => {
                panic!("long request finished before cancel")
            }
            _ => {}
        }
    }
    h_long.cancel();
    let cancelled = h_long.join().unwrap();
    assert_eq!(cancelled.finish, FinishReason::Cancelled);
    assert!(
        cancelled.generated.len() >= 2 && cancelled.generated.len() < 64,
        "expected a partial stream, got {} tokens",
        cancelled.generated.len()
    );
    assert_eq!(
        cancelled.generated[..],
        long_want[..cancelled.generated.len()],
        "cancelled prefix diverged"
    );

    // The freed slot is reused: the third request joins the surviving
    // one and they batch together (occupancy above 1 for both).
    let h_after = cluster.submit(after.clone()).unwrap();
    let mid_res = h_mid.join().unwrap();
    let after_res = h_after.join().unwrap();
    cluster.shutdown();
    assert_eq!(mid_res.generated, mid_want, "survivor diverged after cancel");
    assert_eq!(after_res.generated, after_want, "slot reuse diverged");
    assert!(
        after_res.metrics.decode.mean_batch_occupancy() > 1.0,
        "reused slot never batched with the survivor: occupancy {}",
        after_res.metrics.decode.mean_batch_occupancy()
    );
}

/// Cancellation: cancelling one of two in-flight requests mid-decode
/// frees its slot while the other request (and a subsequently submitted
/// one) complete with unchanged tokens.
#[test]
fn cancel_mid_decode_keeps_cluster_serving() {
    let Some(dir) = artifacts_dir() else { return };
    let long = Request::new(30, vec![3, 141, 59, 26], 64);
    let short = Request::new(31, vec![10, 20, 30], 6);
    let long_want = dense_tokens(&dir, &long);
    let short_want = dense_tokens(&dir, &short);

    let mut cfg = LiveConfig::new(dir.clone(), 2);
    cfg.max_active = 2;
    let cluster = LiveCluster::start(cfg).unwrap();
    let h_long = cluster.submit(long).unwrap();
    let h_short = cluster.submit(short).unwrap();

    // Wait until the long request is demonstrably mid-decode, then
    // cancel it.
    let mut seen = 0;
    while seen < 2 {
        match h_long.next_event().expect("stream died") {
            TokenEvent::Token { .. } => seen += 1,
            TokenEvent::Done { .. } | TokenEvent::Failed { .. } => {
                panic!("long request finished before cancel")
            }
            _ => {}
        }
    }
    h_long.cancel();
    let cancelled = h_long.join().unwrap();
    assert_eq!(cancelled.finish, FinishReason::Cancelled);
    assert!(
        cancelled.generated.len() >= 2 && cancelled.generated.len() < 64,
        "expected a partial stream, got {} tokens",
        cancelled.generated.len()
    );
    // The partial tokens are a prefix of the uncancelled stream.
    assert_eq!(
        cancelled.generated[..],
        long_want[..cancelled.generated.len()],
        "cancelled prefix diverged"
    );

    // The concurrent request is untouched...
    let short_res = h_short.join().unwrap();
    assert_eq!(short_res.generated, short_want);
    // ...and the cluster keeps serving new requests afterwards.
    let after = serve_one(&cluster, &Request::new(32, vec![9, 9], 4));
    assert_eq!(after.generated.len(), 4);
    assert_eq!(after.finish, FinishReason::Length);
    cluster.shutdown();
}

/// Per-request stop tokens: generation halts on the stop token (kept as
/// the last output token, finish reason `Stop`) — replicated across the
/// decentralized nodes.
#[test]
fn stop_tokens_halt_generation() {
    let Some(dir) = artifacts_dir() else { return };
    let req = Request::new(40, vec![3, 141, 59, 26], 8);
    let want = dense_tokens(&dir, &req);
    assert!(want.len() >= 3);
    // Stop on the latest token whose value does not occur earlier in the
    // stream (greedy decode may repeat tokens; the first occurrence is
    // where generation must halt).
    let j = (0..want.len())
        .rev()
        .find(|&j| !want[..j].contains(&want[j]))
        .unwrap();

    let mut stopped = req.clone();
    stopped.sampling.stop = vec![want[j]];
    let cluster = LiveCluster::start(LiveConfig::new(dir, 2)).unwrap();
    let res = serve_one(&cluster, &stopped);
    cluster.shutdown();
    assert_eq!(res.finish, FinishReason::Stop);
    assert_eq!(res.generated, want[..=j].to_vec());
}

/// The Drop satellite: a cluster abandoned without `shutdown()` (the
/// early-`?` path in CLI commands and tests) must join its node threads
/// and fail the in-flight work instead of leaking threads.
#[test]
fn dropping_cluster_joins_threads_and_fails_inflight() {
    let Some(dir) = artifacts_dir() else { return };
    let cluster = LiveCluster::start(LiveConfig::new(dir, 2)).unwrap();
    let handle = cluster.submit(Request::new(50, vec![1, 2, 3], 200)).unwrap();
    drop(cluster); // no shutdown() — Drop must tear everything down
    // The in-flight request ends in a terminal failure (or a closed
    // stream), never a hang.
    assert!(handle.join().is_err(), "abandoned request should fail");
}

/// Like [`drain`], but also collect the per-token logprobs (the device
/// sampler returns them from the on-device full-softmax; host and
/// device values must agree to f32 accumulation error).
fn drain_lp(handle: &apple_moe::engine::RequestHandle) -> (Vec<u32>, Vec<f32>, RequestResult) {
    let mut streamed = Vec::new();
    let mut lps = Vec::new();
    loop {
        match handle.next_event().expect("stream ended without terminal event") {
            TokenEvent::Started { .. } => {}
            TokenEvent::Token { id, logprob } => {
                streamed.push(id);
                lps.push(logprob.expect("live engines report logprobs"));
            }
            TokenEvent::Done { result } => return (streamed, lps, result),
            TokenEvent::Failed { error, .. } => panic!("request failed: {error}"),
        }
    }
}

/// The PR 6 tentpole acceptance: the on-device sampler generates
/// tokens IDENTICAL to the host reference sampler — across both
/// topologies, serial and batched serving (B ∈ {1, 2, 4}), greedy and
/// seeded top-k streams, and stop-token requests (finish-reason
/// parity) — with logprobs agreeing to f32 accumulation error.
#[test]
fn device_sampler_matches_host_sampler_reference() {
    let Some(dir) = artifacts_dir() else { return };
    let manifest = apple_moe::runtime::Manifest::load(&dir).unwrap();
    if !manifest.sampler_artifacts || !batched_artifacts(&dir, 4) {
        eprintln!("skipping: artifacts predate the dev_sample_* set");
        return;
    }

    // Mixed request set: greedy, two distinct top-k streams, and a
    // greedy request with a REAL stop token (derived from the dense
    // stream, first occurrence) so `FinishReason::Stop` parity is
    // exercised on the device stop role, not just the length path.
    let greedy = Request::new(90, vec![3, 141, 59, 26], 8);
    let want = dense_tokens(&dir, &greedy);
    let j = (0..want.len())
        .rev()
        .find(|&j| !want[..j].contains(&want[j]))
        .unwrap();
    let mut topk_a = Request::new(91, vec![10, 20, 30], 8);
    topk_a.sampling.sampler = Sampler::TopK { k: 8, temperature: 0.9 };
    topk_a.sampling.seed = 0xBEEF_CAFE;
    let mut topk_b = Request::new(92, vec![100, 200], 8);
    topk_b.sampling.sampler = Sampler::TopK { k: 3, temperature: 1.3 };
    topk_b.sampling.seed = 7;
    let mut stopped = Request::new(93, vec![3, 141, 59, 26], 8);
    stopped.sampling.stop = vec![want[j]];
    let reqs = [greedy, topk_a, topk_b, stopped];

    for topology in [Topology::Decentralized, Topology::Centralized] {
        for concurrency in [1usize, 2, 4] {
            let run = |host_sampler: bool| -> Vec<(Vec<u32>, Vec<f32>, RequestResult)> {
                let mut cfg = LiveConfig::new(dir.clone(), 2);
                cfg.topology = topology;
                if topology == Topology::Centralized {
                    cfg.balancing = Balancing::SelectedOnly;
                }
                cfg.max_active = concurrency;
                cfg.host_sampler = host_sampler;
                let cluster = LiveCluster::start(cfg).unwrap();
                let handles: Vec<_> =
                    reqs.iter().map(|r| cluster.submit(r.clone()).unwrap()).collect();
                let out = handles.iter().map(drain_lp).collect();
                cluster.shutdown();
                out
            };
            let host = run(true);
            let dev = run(false);
            for ((ht, hl, hr), (dt, dl, dr)) in host.iter().zip(&dev) {
                assert_eq!(
                    dt, ht,
                    "device sampler tokens diverge from host reference \
                     ({topology:?}, c{concurrency}, req {})",
                    hr.id
                );
                assert_eq!(
                    dr.finish, hr.finish,
                    "finish reason diverges ({topology:?}, c{concurrency}, req {})",
                    hr.id
                );
                // Host logprobs accumulate in f64, device in f32.
                for (i, (a, b)) in dl.iter().zip(hl).enumerate() {
                    assert!(
                        (a - b).abs() < 1e-3,
                        "logprob diverges ({topology:?}, c{concurrency}, req {}, tok {i}): \
                         {a} vs {b}",
                        hr.id
                    );
                }
            }
            // The stop request actually stopped — on BOTH samplers.
            assert_eq!(dev[3].2.finish, FinishReason::Stop);
            assert_eq!(dev[3].0, want[..=j].to_vec());
        }
    }
}

fn prefill_artifacts(dir: &Path) -> bool {
    let manifest = apple_moe::runtime::Manifest::load(dir).unwrap();
    if manifest.prefill_chunk_max < 8 {
        eprintln!("skipping: artifacts predate the dev_p* chunked-prefill set");
        return false;
    }
    true
}

/// The PR 10 tentpole acceptance: chunked prefill (`dev_p{T}` [T, D]
/// chunks + mixed iterations) generates tokens IDENTICAL to serial
/// token-by-token prompt evaluation — across both topologies and 1/2
/// nodes — while the prompt phase issues >= 4x fewer executable
/// dispatches per token. The 77-token prompt covers both compiled
/// chunk sizes AND a padded ragged tail in one pass: 76 chunkable
/// positions run as 32 + 32 + 8 + 8-padded-to-4 real rows, and the
/// last prompt token always takes the decode path (it must sample).
#[test]
fn chunked_prefill_matches_serial_on_both_topologies() {
    let Some(dir) = artifacts_dir() else { return };
    if !prefill_artifacts(&dir) {
        return;
    }
    let req = Request::synthetic(100, 77, 512, 6);

    for topology in [Topology::Decentralized, Topology::Centralized] {
        for nodes in [1usize, 2] {
            let run = |prefill_chunk: usize| {
                let mut cfg = LiveConfig::new(dir.clone(), nodes);
                cfg.topology = topology;
                if topology == Topology::Centralized {
                    cfg.balancing = Balancing::SelectedOnly;
                }
                cfg.prefill_chunk = prefill_chunk;
                let cluster = LiveCluster::start(cfg).unwrap();
                let res = serve_one(&cluster, &req);
                cluster.shutdown();
                res
            };
            let serial = run(1);
            let chunked = run(32);
            assert_eq!(
                chunked.generated, serial.generated,
                "chunked prefill diverged from serial ({topology:?} x {nodes} nodes)"
            );
            // Dispatch amortization on the prompt phase (the acceptance
            // floor is 4x; chunk 32 over 76 positions lands ~15x).
            let se = serial.metrics.prefill.exec_calls_per_token();
            let ce = chunked.metrics.prefill.exec_calls_per_token();
            assert!(se > 0.0 && ce > 0.0, "prefill dispatches not metered");
            assert!(
                ce * 4.0 <= se,
                "prompt dispatches not amortized >=4x ({topology:?} x {nodes}): \
                 {ce} vs serial {se}"
            );
            // The [32, D] chunk really ran: 32 positions shared a train.
            assert!(
                chunked.metrics.prefill.occupancy.max() >= 32.0,
                "no 32-row chunk observed ({topology:?} x {nodes}): max {}",
                chunked.metrics.prefill.occupancy.max()
            );
        }
    }

    // The T=8 cap (the acceptance's "drops >=4x at T=8"): identical
    // tokens and >=4x fewer prompt dispatches with ONLY dev_p8 chunks.
    let mut cfg = LiveConfig::new(dir.clone(), 2);
    cfg.prefill_chunk = 1;
    let serial = {
        let cluster = LiveCluster::start(cfg).unwrap();
        let res = serve_one(&cluster, &req);
        cluster.shutdown();
        res
    };
    let mut cfg = LiveConfig::new(dir, 2);
    cfg.prefill_chunk = 8;
    let t8 = {
        let cluster = LiveCluster::start(cfg).unwrap();
        let res = serve_one(&cluster, &req);
        cluster.shutdown();
        res
    };
    assert_eq!(t8.generated, serial.generated, "T=8 chunked prefill diverged");
    let (se, ce) =
        (serial.metrics.prefill.exec_calls_per_token(), t8.metrics.prefill.exec_calls_per_token());
    assert!(ce * 4.0 <= se, "T=8 prompt dispatches not amortized >=4x: {ce} vs {se}");
    assert!(t8.metrics.prefill.occupancy.max() <= 8.0, "T=8 cap ignored");
}

/// Padded / ragged chunk shapes stay bit-identical to the dense
/// reference: a prompt short enough that its ONLY chunk is padded
/// (6 tokens -> one dev_p8 with 5 real rows), a chunk-plus-lone-serial
/// tail (10 tokens -> one full dev_p8, then a single position too
/// short to chunk), and an exact two-chunk fit (41 tokens -> 32 + 8).
#[test]
fn ragged_tail_chunks_match_dense() {
    let Some(dir) = artifacts_dir() else { return };
    if !prefill_artifacts(&dir) {
        return;
    }
    let reqs = [
        Request::synthetic(130, 6, 512, 5),
        Request::synthetic(131, 10, 512, 5),
        Request::synthetic(132, 41, 512, 5),
    ];
    let want: Vec<Vec<u32>> = reqs.iter().map(|r| dense_tokens(&dir, r)).collect();

    let mut cfg = LiveConfig::new(dir, 2);
    cfg.prefill_chunk = 32;
    let cluster = LiveCluster::start(cfg).unwrap();
    for (r, w) in reqs.iter().zip(&want) {
        let res = serve_one(&cluster, r);
        assert_eq!(
            &res.generated, w,
            "ragged-tail chunked prefill diverged (req {}, prompt {})",
            r.id,
            r.prompt.len()
        );
    }
    cluster.shutdown();
}

/// Mixed prefill/decode iterations: a short request's decode tokens —
/// emitted WHILE the long prompt is still chunking — are identical to
/// one-at-a-time serial serving, and the short request's first token
/// beats the long one's (the long prompt no longer monopolizes
/// iterations).
#[test]
fn decode_during_prefill_matches_serial_schedule() {
    let Some(dir) = artifacts_dir() else { return };
    if !prefill_artifacts(&dir) || !batched_artifacts(&dir, 2) {
        return;
    }
    let long = Request::synthetic(110, 96, 512, 6);
    let short = Request::synthetic(111, 4, 512, 12);

    let mk = |max_active: usize| {
        let mut cfg = LiveConfig::new(dir.clone(), 2);
        cfg.max_active = max_active;
        cfg.policy = SchedPolicy::RunToCompletion;
        LiveCluster::start(cfg).unwrap()
    };

    // Serial reference: one request at a time.
    let serial = mk(1);
    let long_want = serve_one(&serial, &long).generated;
    let short_want = serve_one(&serial, &short).generated;
    serial.shutdown();

    // Mixed: both admitted at once; the long prompt chunks while the
    // short request prefills serially alongside and then decodes.
    let cluster = mk(2);
    let h_long = cluster.submit(long).unwrap();
    let h_short = cluster.submit(short).unwrap();
    let long_res = h_long.join().unwrap();
    let short_res = h_short.join().unwrap();
    cluster.shutdown();

    assert_eq!(long_res.generated, long_want, "long request diverged under mixing");
    assert_eq!(short_res.generated, short_want, "decode-during-prefill diverged");
    // Interleaving evidence: the short request needs ~4 iterations to
    // its first token, the 96-token prompt ~6 chunk steps — so the
    // short one must come out first (serial run-to-completion cannot
    // do this: the long request was submitted first).
    assert!(
        short_res.metrics.ttft_s() < long_res.metrics.ttft_s(),
        "short request did not overtake the long prefill: ttft {} vs {}",
        short_res.metrics.ttft_s(),
        long_res.metrics.ttft_s()
    );
    // The long prompt really ran chunked while the short one decoded.
    assert!(long_res.metrics.prefill.occupancy.max() >= 32.0, "long prompt never chunked");
}

/// Cancelling a request while its prompt is still chunking frees the
/// slot: the queued request behind it is admitted and serves identical
/// tokens, and the cluster keeps serving chunked prompts afterwards.
/// The 239-token prompt needs ~10 mixed iterations before its first
/// token; the cancel flag lands within microseconds of submission, so
/// the cancellation is always mid-prefill (zero tokens out).
#[test]
fn mid_prefill_cancel_frees_slot() {
    let Some(dir) = artifacts_dir() else { return };
    if !prefill_artifacts(&dir) {
        return;
    }
    let long = Request::synthetic(120, 239, 512, 8);
    let short = Request::synthetic(121, 3, 512, 6);
    let short_want = dense_tokens(&dir, &short);

    let mut cfg = LiveConfig::new(dir.clone(), 2);
    cfg.max_active = 1; // the long request owns the only slot
    cfg.prefill_chunk = 32;
    let cluster = LiveCluster::start(cfg).unwrap();
    let h_long = cluster.submit(long).unwrap();
    let h_short = cluster.submit(short).unwrap();
    h_long.cancel();
    let cancelled = h_long.join().unwrap();
    assert_eq!(cancelled.finish, FinishReason::Cancelled);
    assert!(
        cancelled.generated.is_empty(),
        "cancel should land mid-prefill, before any token; got {}",
        cancelled.generated.len()
    );

    // The freed slot admits the queued request; tokens are identical.
    let short_res = h_short.join().unwrap();
    assert_eq!(short_res.generated, short_want, "queued request diverged after cancel");
    // And a fresh chunked-prefill request still serves correctly.
    let after = serve_one(&cluster, &Request::synthetic(122, 77, 512, 4));
    assert_eq!(after.generated.len(), 4);
    assert_eq!(after.finish, FinishReason::Length);
    assert!(after.metrics.prefill.occupancy.max() >= 32.0, "post-cancel prompt never chunked");
    cluster.shutdown();
}

/// The headline perf claim, metered end to end: on a single-node
/// cluster (whose decode d2h is exactly router top-k + logits — no
/// multi-node partial downloads diluting the ratio) sampling on device
/// cuts decode d2h bytes/token by >= 10x vs the `[1, V]` logits
/// download of the host-sampler path, with identical tokens.
#[test]
fn device_sampler_collapses_decode_d2h() {
    let Some(dir) = artifacts_dir() else { return };
    let manifest = apple_moe::runtime::Manifest::load(&dir).unwrap();
    if !manifest.device_artifacts || !manifest.sampler_artifacts {
        eprintln!("skipping: artifacts predate the dev_sample_* set");
        return;
    }
    let logits_bytes = 4.0 * manifest.vocab as f64;

    let run = |host_sampler: bool| {
        let mut cfg = LiveConfig::new(dir.clone(), 1);
        cfg.host_sampler = host_sampler;
        let cluster = LiveCluster::start(cfg).unwrap();
        let res = serve_one(&cluster, &Request::new(95, vec![3, 141, 59, 26], 12));
        cluster.shutdown();
        res
    };
    let host = run(true);
    let dev = run(false);
    assert_eq!(dev.generated, host.generated, "sampler paths diverged");

    let host_bpt = host.metrics.decode.d2h_bytes_per_token();
    let dev_bpt = dev.metrics.decode.d2h_bytes_per_token();
    assert!(
        host_bpt > logits_bytes,
        "host path must download the [1, V] logits every token: {host_bpt} B/token"
    );
    assert!(
        dev_bpt < logits_bytes / 8.0,
        "device path still downloading logits-scale data: {dev_bpt} B/token"
    );
    assert!(
        dev_bpt < host_bpt / 10.0,
        "d2h not collapsed >=10x: {dev_bpt} vs {host_bpt} B/token"
    );
}
