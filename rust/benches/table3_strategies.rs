//! Table 3: token-generation throughput and MoE/Comm/Misc breakdown of
//! Naive vs P-L_B vs P-L_R-D on a two-node cluster (single user, 128
//! prompt / 128 generated tokens), plus the footnote-3 prompt-eval rows.

use apple_moe::cluster::sim::{ClusterSim, SimParams};
use apple_moe::config::{ClusterConfig, EngineConfig, Strategy};
use apple_moe::util::bench::{compare, section};
use apple_moe::util::fmt::render_table;

fn main() {
    section("Table 3 — two-node strategy comparison (virtual time, dbrx-132b)");
    let paper: [(Strategy, f64, f64, [f64; 3]); 3] = [
        (Strategy::Naive, 1.2, 0.857, [0.378, 0.357, 0.122]),
        (Strategy::PLb, 2.1, 0.485, [0.240, 0.168, 0.077]),
        (Strategy::PLrD, 6.1, 0.166, [0.081, 0.038, 0.047]),
    ];
    let paper_prefill = [2.8, 4.8, 10.9];

    let mut rows = vec![vec![
        "Method".to_string(),
        "gen TP".to_string(),
        "s/token".to_string(),
        "MoE".to_string(),
        "Comm.".to_string(),
        "Misc".to_string(),
        "prefill TP".to_string(),
    ]];
    let mut measured = Vec::new();
    for (strategy, ..) in &paper {
        let cluster = ClusterConfig::new(2, *strategy);
        let mut sim = ClusterSim::new(cluster, EngineConfig::default(), SimParams::default());
        let m = sim.run_request();
        let (moe, comm, misc) = m.decode.breakdown_secs();
        rows.push(vec![
            format!("{strategy}"),
            format!("{:.1}", m.decode.tokens_per_sec()),
            format!("{:.3}", m.decode.secs_per_token()),
            format!("{moe:.3}"),
            format!("{comm:.3}"),
            format!("{misc:.3}"),
            format!("{:.1}", m.prefill.tokens_per_sec()),
        ]);
        measured.push(m);
    }
    print!("{}", render_table(&rows));

    section("paper vs measured");
    for (i, (strategy, tp, spt, bd)) in paper.iter().enumerate() {
        let m = &measured[i];
        compare(&format!("{strategy} gen throughput"), *tp, m.decode.tokens_per_sec(), "tok/s");
        compare(&format!("{strategy} s/token"), *spt, m.decode.secs_per_token(), "s");
        let (moe, comm, misc) = m.decode.breakdown_secs();
        compare(&format!("{strategy} MoE"), bd[0], moe, "s");
        compare(&format!("{strategy} Comm"), bd[1], comm, "s");
        compare(&format!("{strategy} Misc"), bd[2], misc, "s");
        compare(&format!("{strategy} prompt eval"), paper_prefill[i],
            m.prefill.tokens_per_sec(), "tok/s");
    }

    section("headline speedups (§5.2)");
    let naive_moe = measured[0].decode.breakdown_secs().0;
    compare("P-L_B MoE speedup", 1.7, naive_moe / measured[1].decode.breakdown_secs().0, "x");
    compare("P-L_R-D MoE speedup", 5.2, naive_moe / measured[2].decode.breakdown_secs().0, "x");
}
