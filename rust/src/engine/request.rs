//! Requests and results for the serving loop.

use crate::metrics::RunMetrics;

/// One generation request (the paper's workload is single-user, prompt
/// and generation capped at 128 tokens; Table 5 uses 2000/256).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<u32>,
    pub max_new_tokens: usize,
}

impl Request {
    pub fn new(id: u64, prompt: Vec<u32>, max_new_tokens: usize) -> Request {
        Request { id, prompt, max_new_tokens }
    }

    /// Synthetic prompt of `len` tokens over `vocab` (seeded by id).
    pub fn synthetic(id: u64, len: usize, vocab: usize) -> Request {
        let mut rng = crate::util::rng::Rng::new(0xFEED ^ id);
        let prompt = (0..len).map(|_| rng.below(vocab as u64) as u32).collect();
        Request { id, prompt, max_new_tokens: 128 }
    }
}

/// Completed request.
#[derive(Debug, Clone)]
pub struct RequestResult {
    pub id: u64,
    pub generated: Vec<u32>,
    pub metrics: RunMetrics,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synthetic_prompt_in_vocab() {
        let r = Request::synthetic(7, 128, 512);
        assert_eq!(r.prompt.len(), 128);
        assert!(r.prompt.iter().all(|&t| t < 512));
    }

    #[test]
    fn synthetic_is_deterministic_per_id() {
        assert_eq!(Request::synthetic(1, 16, 512), Request::synthetic(1, 16, 512));
        assert_ne!(Request::synthetic(1, 16, 512), Request::synthetic(2, 16, 512));
    }
}
