"""L1 Pallas kernel: weighted combine of expert-slot outputs.

Computes ``out = sum_s w[s] * ys[s]`` — the per-node partial of the
weighted sum whose cross-node completion is the Fig. 7 all-reduce.
Padding slots (busy-full extras, LRU keep-warm runs) carry weight 0, so
"zero out their response during the weighted sum" (§4.2) is literally
this kernel.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _combine_kernel(ys_ref, w_ref, o_ref):
    """Single-block kernel: ys [S, T, D], w [S], out [T, D]."""
    ys = ys_ref[...]
    w = w_ref[...]
    o_ref[...] = jnp.einsum("s,std->td", w, ys)


def combine_weighted(ys, w):
    """Weighted sum over the slot axis.

    Args:
      ys: [S, T, D] slot outputs.
      w:  [S] combine weights (0 for padding slots).

    Returns:
      [T, D].
    """
    s, t, d = ys.shape
    return pl.pallas_call(
        _combine_kernel,
        out_shape=jax.ShapeDtypeStruct((t, d), ys.dtype),
        interpret=True,
    )(ys, w)
