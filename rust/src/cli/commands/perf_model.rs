//! `apple-moe perf-model` — Eq. 1 bounds: Table 6 (10 GbE, 2–8 nodes)
//! and the Fig. 8 NIC projections.

use anyhow::Result;

use crate::cli::args::Args;
use crate::config::{ModelDims, NetworkProfile, NodeHardware};
use crate::perfmodel::eq1::{default_expected_experts, estimate, PerfModelInputs};
use crate::util::fmt::render_table;

pub fn run(args: &mut Args) -> Result<()> {
    let max_nodes = args.usize_or("max-nodes", 8)?;
    let seed = args.u64_or("seed", 0xE1)?;
    args.finish()?;

    let node_counts: Vec<usize> =
        [2usize, 3, 4, 6, 8].into_iter().filter(|&n| n <= max_nodes).collect();

    for profile in [
        NetworkProfile::tcp_10gbe(),
        NetworkProfile::rocev2(),
        NetworkProfile::infiniband(),
    ] {
        println!("# Eq. 1 bounds with {} (latency {} ns)\n", profile.name, profile.latency_ns);
        let mut rows = vec![vec![
            "#".to_string(),
            "E[experts]".to_string(),
            "Load (s)".to_string(),
            "Comp. (s)".to_string(),
            "Lat. (s)".to_string(),
            "Trans. (s)".to_string(),
            "Time (s)".to_string(),
            "TP (tok/s)".to_string(),
        ]];
        for &n in &node_counts {
            let e = default_expected_experts(n, seed);
            let est = estimate(&PerfModelInputs {
                model: ModelDims::dbrx_132b(),
                hardware: NodeHardware::m2_ultra(),
                network: profile.clone(),
                n_nodes: n,
                expected_experts: e,
            });
            rows.push(vec![
                n.to_string(),
                format!("{e:.2}"),
                format!("{:.3}", est.load_secs),
                format!("{:.3}", est.compute_secs),
                format!("{:.3}", est.latency_secs),
                format!("{:.3}", est.transfer_secs),
                format!("{:.3}", est.total_secs),
                format!("{:.1}", est.tokens_per_sec),
            ]);
        }
        print!("{}", render_table(&rows));
        println!();
    }
    Ok(())
}
