//! Mixture-of-Experts coordination: router draws, expert→node assignment,
//! the three load-balancing strategies of §4.2, LRU expert tracking, and
//! the weighted combine.
//!
//! This module is pure logic shared verbatim by the virtual-time DES
//! (`cluster::sim`) and the live threaded cluster (`cluster::live`) — the
//! paper's contribution is exactly this coordination layer, so it must be
//! identical in both execution modes.

pub mod balance;
pub mod combine;
pub mod lru;
pub mod router;

pub use balance::{ExpertRun, LayerPlan, NodeWork, Planner};
pub use lru::LruTracker;
pub use router::{RouterDraw, SyntheticRouter};
