//! Simulated unified-memory manager — the "driver processing" the paper
//! observes in Apple's Metal driver (§3.2, Figs. 4–5).
//!
//! The real driver is closed source; the paper characterizes it
//! behaviourally and so do we. The model, calibrated against Fig. 4:
//!
//! - Before the GPU may compute on an array, the array must be **wired**
//!   (resident and unpageable). Wiring costs a fixed per-array driver call
//!   plus `bytes / wire_bw` (the prestacked 32 GB benchmark array takes
//!   ≈400 ms to wire ⇒ `wire_bw` ≈ 80 GB/s).
//! - A wired array that has not been touched for an inactivity window is
//!   **unwired** (a protection mechanism against GPU memory pressure —
//!   the paper's conjecture). The window grows slowly with array size:
//!   ≈300 ms for the 268 MB unstacked matrices (so Fig. 4's unstacked
//!   curve departs once the inter-touch gap `40×(c+T_wait)` exceeds it,
//!   i.e. at `T_wait ≈ 8 ms`) and 512 ms for multi-GB prestacked stacks
//!   (so the prestacked curve departs at `T_wait ≈ 512 ms`).
//! - Warmup wires everything up front (Algorithm 2 line 6); the
//!   `P-L_R-D` standby computation (§4.2) is a `touch_all` between
//!   requests.
//!
//! The simulator is deterministic and runs on any `Clock`-compatible
//! timestamp stream: callers pass explicit `now` values in nanoseconds.

pub mod params;

pub use params::DriverParams;

use std::collections::HashMap;

use crate::model::weights::{ArrayId, WeightArray};
use crate::simclock::Nanos;

/// One wiring event, for Fig. 5-style timeline traces.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WireEvent {
    /// Simulation time at which the driver began wiring.
    pub at: Nanos,
    pub id: ArrayId,
    pub bytes: u64,
    /// Driver processing time charged.
    pub cost: Nanos,
    /// True if this was a re-wire of a previously wired array (the
    /// "repeated payment" of §4.2).
    pub rewire: bool,
}

#[derive(Debug, Clone, Copy)]
struct WiredState {
    last_touch: Nanos,
    bytes: u64,
}

/// Cumulative driver statistics.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct DriverStats {
    pub wire_ops: u64,
    pub rewire_ops: u64,
    pub wired_bytes_total: u64,
    pub driver_ns_total: Nanos,
}

/// The simulated driver for one node.
#[derive(Debug)]
pub struct DriverSim {
    params: DriverParams,
    wired: HashMap<ArrayId, WiredState>,
    stats: DriverStats,
    trace: Option<Vec<WireEvent>>,
}

impl DriverSim {
    pub fn new(params: DriverParams) -> DriverSim {
        DriverSim { params, wired: HashMap::new(), stats: DriverStats::default(), trace: None }
    }

    /// Enable event tracing (Fig. 5 timelines).
    pub fn with_trace(mut self) -> DriverSim {
        self.trace = Some(Vec::new());
        self
    }

    pub fn params(&self) -> &DriverParams {
        &self.params
    }

    pub fn stats(&self) -> DriverStats {
        self.stats
    }

    pub fn trace(&self) -> &[WireEvent] {
        self.trace.as_deref().unwrap_or(&[])
    }

    /// Is `id` wired at time `now` (i.e. wired and not idle-expired)?
    pub fn is_wired(&self, id: ArrayId, now: Nanos) -> bool {
        match self.wired.get(&id) {
            None => false,
            Some(s) => {
                now.saturating_sub(s.last_touch) <= self.params.unwire_after(s.bytes)
            }
        }
    }

    /// Touch `arrays` for GPU compute starting at `now`. Returns the
    /// driver processing time that must elapse before compute may start
    /// (0 if everything is already wired and fresh). Updates last-touch
    /// stamps to the end of the driver work.
    pub fn touch(&mut self, arrays: &[WeightArray], now: Nanos) -> Nanos {
        let mut cost: Nanos = 0;
        for a in arrays {
            let expired = match self.wired.get(&a.id) {
                None => None, // never wired
                Some(s) => {
                    let idle = now.saturating_sub(s.last_touch);
                    if idle > self.params.unwire_after(s.bytes) {
                        Some(true) // unwired by inactivity -> re-wire
                    } else {
                        Some(false) // still wired
                    }
                }
            };
            let needs_wire = !matches!(expired, Some(false));
            if needs_wire {
                let rewire = expired == Some(true);
                let c = if rewire {
                    self.params.rewire_cost(a.bytes)
                } else {
                    self.params.wire_cost(a.bytes)
                };
                self.stats.wire_ops += 1;
                if rewire {
                    self.stats.rewire_ops += 1;
                }
                self.stats.wired_bytes_total += a.bytes;
                self.stats.driver_ns_total += c;
                if let Some(t) = &mut self.trace {
                    t.push(WireEvent { at: now + cost, id: a.id, bytes: a.bytes, cost: c, rewire });
                }
                cost += c;
            }
        }
        // All touched arrays are stamped at the moment compute can begin.
        let stamp = now + cost;
        for a in arrays {
            self.wired.insert(a.id, WiredState { last_touch: stamp, bytes: a.bytes });
        }
        cost
    }

    /// Refresh last-touch stamps without charging wiring (models compute
    /// *finishing* at `now`: the GPU referenced the data up to this
    /// point). Only refreshes arrays that are currently wired.
    pub fn refresh(&mut self, arrays: &[WeightArray], now: Nanos) {
        for a in arrays {
            if let Some(s) = self.wired.get_mut(&a.id) {
                if now > s.last_touch {
                    s.last_touch = now;
                }
            }
        }
    }

    /// Warm up: wire every array, returning total driver time (system
    /// startup / Algorithm 2 warmup). Equivalent to `touch`, named for
    /// intent.
    pub fn warmup(&mut self, arrays: &[WeightArray], now: Nanos) -> Nanos {
        self.touch(arrays, now)
    }

    /// Number of arrays currently wired (fresh) at `now`.
    pub fn wired_count(&self, now: Nanos) -> usize {
        self.wired
            .values()
            .filter(|s| now.saturating_sub(s.last_touch) <= self.params.unwire_after(s.bytes))
            .count()
    }

    /// Bytes currently wired (fresh) at `now`.
    pub fn wired_bytes(&self, now: Nanos) -> u64 {
        self.wired
            .values()
            .filter(|s| now.saturating_sub(s.last_touch) <= self.params.unwire_after(s.bytes))
            .map(|s| s.bytes)
            .sum()
    }

    /// Reset all wiring state (e.g. after a simulated reboot).
    pub fn reset(&mut self) {
        self.wired.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simclock::NS_PER_MS;

    const MB: u64 = 1024 * 1024;
    const GB: u64 = 1024 * MB;

    fn arr(n: u16, bytes: u64) -> WeightArray {
        WeightArray { id: ArrayId::ExpertStack { expert: n }, bytes }
    }

    #[test]
    fn first_touch_pays_wire_cost() {
        let mut d = DriverSim::new(DriverParams::default());
        let a = [arr(0, GB)];
        let c = d.touch(&a, 0);
        assert!(c > 0);
        assert_eq!(d.stats().wire_ops, 1);
        assert_eq!(d.stats().rewire_ops, 0);
    }

    #[test]
    fn second_touch_is_free_when_fresh() {
        let mut d = DriverSim::new(DriverParams::default());
        let a = [arr(0, GB)];
        let c0 = d.touch(&a, 0);
        let c1 = d.touch(&a, c0 + NS_PER_MS);
        assert_eq!(c1, 0);
        assert_eq!(d.stats().wire_ops, 1);
    }

    #[test]
    fn idle_expiry_triggers_rewire() {
        let p = DriverParams::default();
        let mut d = DriverSim::new(p.clone());
        let a = [arr(0, 256 * MB)];
        let c0 = d.touch(&a, 0);
        let window = p.unwire_after(256 * MB);
        // Just inside the window: free.
        assert_eq!(d.touch(&a, c0 + window), 0);
        // Now wait past the window from the refreshed stamp: re-wire.
        let last = c0 + window;
        let c2 = d.touch(&a, last + window + NS_PER_MS);
        assert!(c2 > 0);
        assert_eq!(d.stats().rewire_ops, 1);
    }

    #[test]
    fn window_grows_with_size() {
        let p = DriverParams::default();
        assert!(p.unwire_after(32 * GB) > p.unwire_after(256 * MB));
        assert!(p.unwire_after(256 * MB) > p.unwire_after(MB));
        // Fig. 4 anchors: ~512 ms for the 32 GB prestack...
        let big = p.unwire_after(32 * GB);
        assert!(
            (400 * NS_PER_MS..650 * NS_PER_MS).contains(&big),
            "32GB window {} ms",
            big / NS_PER_MS
        );
        // ...and low enough for 268 MB matrices that a 40-layer pass with
        // 8 ms sleeps (~380 ms inter-touch) expires them, while a pass
        // with 4 ms sleeps (~220 ms) does not.
        let small = p.unwire_after(268 * MB);
        assert!(
            (220 * NS_PER_MS..380 * NS_PER_MS).contains(&small),
            "268MB window {} ms",
            small / NS_PER_MS
        );
    }

    #[test]
    fn wire_cost_scales_with_bytes() {
        let p = DriverParams::default();
        // 32 GB prestack wires in ≈400 ms (Finding 2).
        let c = p.wire_cost(32 * GB);
        assert!(
            (300 * NS_PER_MS..520 * NS_PER_MS).contains(&c),
            "32GB wire {} ms",
            c / NS_PER_MS
        );
        assert!(p.wire_cost(2 * GB) > p.wire_cost(GB));
        // Fixed floor for tiny arrays.
        assert!(p.wire_cost(1) >= p.fixed_ns);
    }

    #[test]
    fn refresh_extends_lifetime_without_cost() {
        let p = DriverParams::default();
        let mut d = DriverSim::new(p.clone());
        let a = [arr(0, 256 * MB)];
        d.touch(&a, 0);
        let w = p.unwire_after(256 * MB);
        // Keep refreshing at 80% of the window; never expires.
        let mut t = 0;
        for _ in 0..10 {
            t += w * 8 / 10;
            d.refresh(&a, t);
        }
        assert_eq!(d.touch(&a, t + w / 2), 0);
        assert_eq!(d.stats().wire_ops, 1);
    }

    #[test]
    fn refresh_does_not_wire_unknown_arrays() {
        let mut d = DriverSim::new(DriverParams::default());
        d.refresh(&[arr(9, GB)], 100);
        assert_eq!(d.wired_count(100), 0);
        // First real touch still pays.
        assert!(d.touch(&[arr(9, GB)], 200) > 0);
    }

    #[test]
    fn trace_records_events() {
        let mut d = DriverSim::new(DriverParams::default()).with_trace();
        let a = [arr(0, GB), arr(1, GB)];
        d.touch(&a, 0);
        assert_eq!(d.trace().len(), 2);
        assert!(!d.trace()[0].rewire);
        // Second array's wiring starts after the first finishes.
        assert_eq!(d.trace()[1].at, d.trace()[0].cost);
    }

    #[test]
    fn wired_accounting() {
        let p = DriverParams::default();
        let mut d = DriverSim::new(p.clone());
        let a = [arr(0, GB), arr(1, 2 * GB)];
        let c = d.touch(&a, 0);
        assert_eq!(d.wired_count(c), 2);
        assert_eq!(d.wired_bytes(c), 3 * GB);
        // After both windows pass, nothing is fresh.
        let far = c + p.unwire_after(2 * GB) * 2;
        assert_eq!(d.wired_count(far), 0);
    }

    #[test]
    fn prop_touch_cost_is_monotone_in_cold_set() {
        crate::util::prop::forall("cold arrays cost more", 64, |g| {
            let p = DriverParams::default();
            let mut d1 = DriverSim::new(p.clone());
            let mut d2 = DriverSim::new(p);
            let n = 1 + g.usize_in(0..8);
            let arrays: Vec<WeightArray> =
                (0..n as u16).map(|i| arr(i, (1 + g.u64_in(0..64)) * MB)).collect();
            // d1 pre-warms a prefix, d2 pre-warms everything.
            let split = g.usize_in(0..arrays.len());
            d1.touch(&arrays[..split], 0);
            d2.touch(&arrays, 0);
            let t = NS_PER_MS; // fresh for all windows
            d1.touch(&arrays, t) >= d2.touch(&arrays, t)
        });
    }
}
