//! Quickstart: load the AOT artifacts and stream generated tokens from
//! the dense single-node engine — the smallest end-to-end use of the
//! streaming serving API (`Engine::submit` → `TokenEvent` stream).
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use std::path::Path;

use apple_moe::engine::{DenseEngine, Request, Sampler, TokenEvent};

fn main() -> anyhow::Result<()> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    anyhow::ensure!(
        dir.join("manifest.txt").exists(),
        "artifacts missing — run `make artifacts` first"
    );

    println!("loading dbrx-nano artifacts + compiling on the PJRT CPU client...");
    let engine = DenseEngine::load(&dir)?;
    let m = engine.manifest();
    println!(
        "model: {} layers, d={}, {} experts (top-{}), vocab {}",
        m.n_layers, m.d_embed, m.n_experts, m.top_k, m.vocab
    );

    // Sampling is per-request: this one decodes greedily with a private
    // seed; swap in Sampler::TopK { k, temperature } to sample.
    let mut req = Request::new(1, vec![11, 29, 83, 147], 24);
    req.sampling.sampler = Sampler::Greedy;
    req.sampling.seed = 42;
    println!("prompt:    {:?}", req.prompt);

    // submit() returns at once; tokens stream on the handle as the
    // worker decodes them.
    let handle = engine.submit(req)?;
    print!("generated:");
    let result = loop {
        match handle.next_event().expect("engine dropped the stream") {
            TokenEvent::Started { ttft_s, .. } => {
                eprintln!("(first token after {ttft_s:.2} s)");
            }
            TokenEvent::Token { id, logprob } => {
                print!(" {id}");
                let _ = logprob; // ln p(token) under the full softmax
            }
            TokenEvent::Done { result } => break result,
            TokenEvent::Failed { error, .. } => anyhow::bail!("generation failed: {error}"),
        }
    };
    println!();
    println!("finish:    {:?}", result.finish);
    println!(
        "prefill {:.1} tok/s | decode {:.1} tok/s | ttft {:.2} s | latency {:.2} s",
        result.metrics.prefill.tokens_per_sec(),
        result.metrics.decode.tokens_per_sec(),
        result.metrics.ttft_s(),
        result.metrics.latency_s(),
    );
    Ok(())
}
