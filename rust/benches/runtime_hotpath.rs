//! Live-runtime hot-path microbenchmarks (the §Perf L3 targets): per-role
//! artifact execution latency and the end-to-end live decode step, on
//! real PJRT. Requires `make artifacts`; skips politely otherwise.

// Test code: a panic is the failure report (see clippy.toml).
#![allow(clippy::unwrap_used)]

use std::path::Path;

use apple_moe::cluster::live::{LiveCluster, LiveConfig};
use apple_moe::engine::request::Request;
use apple_moe::metrics::PhaseMetrics;
use apple_moe::runtime::{DeviceState, NanoRuntime};
use apple_moe::util::bench::{report, section, time_runs};

fn main() {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.txt").exists() {
        println!("skipping runtime_hotpath: run `make artifacts` first");
        return;
    }

    section("role-artifact latencies (single PJRT client)");
    let rt = NanoRuntime::load(&dir, true).expect("load");
    let node = rt.build_node_experts(&(0..8).collect::<Vec<_>>()).unwrap();

    let x = rt.embed(1).unwrap();
    report("embed", &time_runs(3, 20, || {
        rt.embed(7).unwrap();
    }));

    let k = rt.empty_layer_cache();
    let v = rt.empty_layer_cache();
    report("attn_router", &time_runs(3, 20, || {
        rt.attn_router(0, &x, &k, &v, 0).unwrap();
    }));

    let ar = rt.attn_router(0, &x, &k, &v, 0).unwrap();
    let idx = vec![0i32; rt.manifest.num_slots];
    let w = vec![0.25f32; rt.manifest.num_slots];
    report("experts pallas-ref (8 slots)", &time_runs(3, 20, || {
        rt.node_experts(&node, 0, &ar.moe_in, &idx, &w).unwrap();
    }));
    let idx4 = vec![0i32; rt.manifest.fast_num_slots];
    let w4 = vec![0.25f32; rt.manifest.fast_num_slots];
    report("experts fast ns4 (serving path)", &time_runs(3, 20, || {
        rt.node_experts_fast(&node, 0, &ar.moe_in, &idx4, &w4).unwrap();
    }));
    report("experts fast ns8 (busy-full path)", &time_runs(3, 20, || {
        rt.node_experts_fast(&node, 0, &ar.moe_in, &idx, &w).unwrap();
    }));
    let lid4 = vec![0usize, 1, 2, 3];
    let lid8: Vec<usize> = (0..8).collect();
    report("experts direct ns4 (production)", &time_runs(3, 20, || {
        rt.node_experts_direct(&node, 0, &ar.moe_in, &lid4, &w4).unwrap();
    }));
    report("experts direct ns8 (busy-full)", &time_runs(3, 20, || {
        rt.node_experts_direct(&node, 0, &ar.moe_in, &lid8, &w).unwrap();
    }));

    report("lm_head", &time_runs(3, 20, || {
        rt.lm_head(&x).unwrap();
    }));

    let kc = rt.empty_dense_cache();
    let vc = rt.empty_dense_cache();
    report("dense_step (whole model)", &time_runs(3, 10, || {
        rt.dense_step(3, &kc, &vc, 0).unwrap();
    }));

    if rt.has_device_path() {
        section("host-roundtrip vs device-resident decode step (single node)");
        // Host path: the fused attn_router round-trips both caches per
        // layer; device path: DeviceState keeps everything on device.
        // Transfer meters accumulate over every closure invocation.
        const WARMUP: usize = 3;
        const SAMPLES: usize = 20;
        const STEPS: f64 = (WARMUP + SAMPLES) as f64;
        let node16 = rt.build_node_experts(&(0..16).collect::<Vec<_>>()).unwrap();
        let m = rt.manifest.clone();
        {
            let mut kcs: Vec<_> = (0..m.n_layers).map(|_| rt.empty_layer_cache()).collect();
            let mut vcs = kcs.clone();
            let mut pos = 0usize;
            rt.take_transfer_stats();
            let samples = time_runs(WARMUP, SAMPLES, || {
                let mut x = rt.embed(7).unwrap();
                for l in 0..m.n_layers {
                    let ar = rt.attn_router(l, &x, &kcs[l], &vcs[l], pos).unwrap();
                    kcs[l] = ar.k_cache;
                    vcs[l] = ar.v_cache;
                    let ids: Vec<usize> = ar
                        .top_i
                        .iter()
                        .map(|&e| node16.local_index(e).unwrap())
                        .collect();
                    let p =
                        rt.node_experts_direct(&node16, l, &ar.moe_in, &ids, &ar.top_w).unwrap();
                    for (xi, (hi, ci)) in x.iter_mut().zip(ar.h.iter().zip(&p)) {
                        *xi = hi + ci;
                    }
                }
                rt.lm_head(&x).unwrap();
                pos = (pos + 1) % m.max_seq;
            });
            let ts = rt.take_transfer_stats();
            report("decode step host-roundtrip", &samples);
            println!(
                "  transfers: {:.1} KiB/step over {STEPS:.0} steps",
                (ts.h2d_bytes + ts.d2h_bytes) as f64 / STEPS / 1024.0
            );
        }
        {
            let mut st = DeviceState::new(&rt).unwrap();
            let mut pos = 0usize;
            rt.take_transfer_stats();
            let samples = time_runs(WARMUP, SAMPLES, || {
                st.begin_token(&rt, 7).unwrap();
                for l in 0..m.n_layers {
                    let (top_w, top_i) = st.attn_router(&rt, l, pos).unwrap();
                    let ids: Vec<usize> =
                        top_i.iter().map(|&e| node16.local_index(e).unwrap()).collect();
                    let p = st.node_experts(&rt, &node16, l, &ids, &top_w).unwrap();
                    st.finish_layer_device(&rt, &p).unwrap();
                }
                st.logits(&rt).unwrap();
                pos = (pos + 1) % m.max_seq;
            });
            let ts = rt.take_transfer_stats();
            report("decode step device-resident", &samples);
            println!(
                "  transfers: {:.1} KiB/step over {STEPS:.0} steps",
                (ts.h2d_bytes + ts.d2h_bytes) as f64 / STEPS / 1024.0
            );
        }
    } else {
        println!("\n(artifacts predate the dev_* set: skipping device-resident section)");
    }

    section("end-to-end live decode (2-node threaded cluster)");
    let run_cluster = |device_resident: bool| -> PhaseMetrics {
        let mut cfg = LiveConfig::new(dir.clone(), 2);
        cfg.device_resident = device_resident;
        let cluster = LiveCluster::start(cfg).expect("cluster");
        let req = Request::synthetic(0, 4, 512, 16);
        let res = cluster.submit(req).unwrap().join().unwrap();
        cluster.shutdown();
        res.metrics.decode.clone()
    };
    for (label, device) in [("host-roundtrip", false), ("device-resident", true)] {
        let d = run_cluster(device);
        let (moe, comm, misc) = d.breakdown_secs();
        println!(
            "decode [{label}]: {:.1} tok/s ({:.4} s/token; MoE {moe:.4} Comm {comm:.4} \
             Misc {misc:.4}; {:.1} KiB/token h<->d, {:.4} s/token in transfers)",
            d.tokens_per_sec(),
            d.secs_per_token(),
            d.transfer_bytes_per_token() / 1024.0,
            d.transfer_secs_per_token(),
        );
    }
}
