"""Pure-jnp oracles for the Pallas kernels (build-time correctness gate).

Every kernel in this package must match its `_ref` twin to float32
tolerance; `python/tests/test_kernels.py` sweeps shapes with hypothesis.
"""

import jax
import jax.numpy as jnp


def expert_ffn_ref(x, w1, v1, w2):
    """One expert: (silu(x @ w1) * (x @ v1)) @ w2."""
    return (jax.nn.silu(x @ w1) * (x @ v1)) @ w2


def expert_ffn_stacked_ref(x, w1s, v1s, w2s):
    """[S,T,D] outputs for stacked expert weights (vmap of the single)."""
    return jax.vmap(lambda a, b, c: expert_ffn_ref(x, a, b, c))(w1s, v1s, w2s)


def combine_weighted_ref(ys, w):
    """sum_s w[s] * ys[s] -> [T, D]."""
    return jnp.einsum("s,std->td", w, ys)


def moe_block_ref(x, w1s, v1s, w2s, top_idx, top_w):
    """Full MoE block: gather selected experts, run, weighted-sum.

    Args:
      x: [T, D]; w1s/v1s/w2s: [E, ...] full expert stacks;
      top_idx: [K] int32; top_w: [K].
    """
    ys = expert_ffn_stacked_ref(
        x, w1s[top_idx], v1s[top_idx], w2s[top_idx]
    )
    return combine_weighted_ref(ys, top_w)
