//! Integration: the rust runtime executes the AOT artifacts and the
//! distributed role composition matches the dense single-step — the
//! load-bearing correctness claim of the whole three-layer stack.
//!
//! Requires `make artifacts` (skipped with a message otherwise).

// Test code: a panic is the failure report (see clippy.toml).
#![allow(clippy::unwrap_used)]

use std::path::{Path, PathBuf};

use apple_moe::runtime::{DeviceState, HostTensor, NanoRuntime};

use apple_moe::engine::{Sampler, SamplingParams};

fn artifacts_dir() -> Option<PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.txt").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: run `make artifacts` first");
        None
    }
}

fn allclose(a: &[f32], b: &[f32], tol: f32) -> bool {
    a.len() == b.len()
        && a.iter().zip(b).all(|(x, y)| (x - y).abs() <= tol * (1.0 + y.abs()))
}

#[test]
fn manifest_and_artifacts_load() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = NanoRuntime::load(&dir, false).expect("load runtime");
    assert_eq!(rt.manifest.n_experts, 16);
    assert_eq!(rt.manifest.top_k, 4);
}

#[test]
fn embed_matches_weight_row() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = NanoRuntime::load(&dir, false).unwrap();
    let x = rt.embed(5).unwrap();
    let table = rt.host_weight("embed").unwrap();
    let d = rt.manifest.d_embed;
    assert!(allclose(&x, &table.data[5 * d..6 * d], 1e-6));
}

#[test]
fn router_output_is_valid_topk() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = NanoRuntime::load(&dir, false).unwrap();
    let x = rt.embed(17).unwrap();
    let k = rt.empty_layer_cache();
    let v = rt.empty_layer_cache();
    let out = rt.attn_router(0, &x, &k, &v, 0).unwrap();
    assert_eq!(out.top_i.len(), 4);
    let mut ids = out.top_i.clone();
    ids.sort_unstable();
    ids.dedup();
    assert_eq!(ids.len(), 4, "duplicate experts {:?}", out.top_i);
    assert!(out.top_i.iter().all(|&e| e < 16));
    let sum: f32 = out.top_w.iter().sum();
    assert!((sum - 1.0).abs() < 1e-4, "weights sum {sum}");
    // KV cache position 0 must now be populated.
    let hd = rt.manifest.head_dim;
    let written: f32 = out.k_cache.data[..hd].iter().map(|x| x.abs()).sum();
    assert!(written > 0.0);
}

/// The headline: distributed expert parallelism over 2 nodes ==
/// the dense single-process step, token for token.
#[test]
fn two_node_distributed_equals_dense() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = NanoRuntime::load(&dir, true).unwrap();
    let m = rt.manifest.clone();
    let ns = m.num_slots;

    // Node expert partitions (Fig. 3).
    let node0 = rt.build_node_experts(&(0..8).collect::<Vec<_>>()).unwrap();
    let node1 = rt.build_node_experts(&(8..16).collect::<Vec<_>>()).unwrap();

    // Dense reference.
    let mut kc_d = rt.empty_dense_cache();
    let mut vc_d = rt.empty_dense_cache();

    // Distributed state: per-layer caches.
    let mut kc: Vec<HostTensor> = (0..m.n_layers).map(|_| rt.empty_layer_cache()).collect();
    let mut vc: Vec<HostTensor> = (0..m.n_layers).map(|_| rt.empty_layer_cache()).collect();

    for (pos, tok) in [3u32, 99, 200, 7].iter().enumerate() {
        let (want_logits, kd, vd) = rt.dense_step(*tok, &kc_d, &vc_d, pos).unwrap();
        kc_d = kd;
        vc_d = vd;

        // Distributed step.
        let mut x = rt.embed(*tok).unwrap();
        for l in 0..m.n_layers {
            let ar = rt.attn_router(l, &x, &kc[l], &vc[l], pos).unwrap();
            kc[l] = ar.k_cache.clone();
            vc[l] = ar.v_cache.clone();
            let mut combined = vec![0.0f32; m.d_embed];
            for node in [&node0, &node1] {
                let mut idx = vec![0i32; ns];
                let mut w = vec![0f32; ns];
                let mut slot = 0;
                for (i, &e) in ar.top_i.iter().enumerate() {
                    if let Some(local) = node.local_index(e) {
                        idx[slot] = local as i32;
                        w[slot] = ar.top_w[i];
                        slot += 1;
                    }
                }
                let partial = rt.node_experts(node, l, &ar.moe_in, &idx, &w).unwrap();
                for (c, p) in combined.iter_mut().zip(&partial) {
                    *c += p; // the all-reduce
                }
            }
            for (xi, (hi, ci)) in x.iter_mut().zip(ar.h.iter().zip(&combined)) {
                *xi = hi + ci;
            }
        }
        let got_logits = rt.lm_head(&x).unwrap();
        assert!(
            allclose(&got_logits, &want_logits, 5e-4),
            "logits diverge at pos {pos}"
        );
    }
}

#[test]
fn sixteen_resident_node_matches_partition() {
    // A single node holding all 16 experts must produce the same MoE
    // output as the 8+8 partition (placement invariance).
    let Some(dir) = artifacts_dir() else { return };
    let rt = NanoRuntime::load(&dir, false).unwrap();
    let m = rt.manifest.clone();
    let ns = m.num_slots;
    let all = rt.build_node_experts(&(0..16).collect::<Vec<_>>()).unwrap();
    let n0 = rt.build_node_experts(&(0..8).collect::<Vec<_>>()).unwrap();
    let n1 = rt.build_node_experts(&(8..16).collect::<Vec<_>>()).unwrap();

    let x = rt.embed(42).unwrap();
    let k = rt.empty_layer_cache();
    let v = rt.empty_layer_cache();
    let ar = rt.attn_router(0, &x, &k, &v, 0).unwrap();

    // All-on-one-node.
    let mut idx = vec![0i32; ns];
    let mut w = vec![0f32; ns];
    for (i, &e) in ar.top_i.iter().enumerate() {
        idx[i] = all.local_index(e).unwrap() as i32;
        w[i] = ar.top_w[i];
    }
    let want = rt.node_experts(&all, 0, &ar.moe_in, &idx, &w).unwrap();

    // Partitioned.
    let mut got = vec![0.0f32; m.d_embed];
    for node in [&n0, &n1] {
        let mut idx = vec![0i32; ns];
        let mut w = vec![0f32; ns];
        let mut slot = 0;
        for (i, &e) in ar.top_i.iter().enumerate() {
            if let Some(local) = node.local_index(e) {
                idx[slot] = local as i32;
                w[slot] = ar.top_w[i];
                slot += 1;
            }
        }
        let p = rt.node_experts(node, 0, &ar.moe_in, &idx, &w).unwrap();
        for (g, x) in got.iter_mut().zip(&p) {
            *g += x;
        }
    }
    assert!(allclose(&got, &want, 1e-4));
}

/// The §Perf tentpole: the device-resident decode path (untupled dev_*
/// executables, caches and activations never leaving the device) must
/// reproduce the host-roundtrip reference path's logits within 1e-5 —
/// while moving orders of magnitude fewer bytes across the host
/// boundary per token.
#[test]
fn device_resident_path_matches_host_path() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = NanoRuntime::load(&dir, false).unwrap();
    if !rt.has_device_path() {
        eprintln!("skipping: artifacts predate the dev_* set");
        return;
    }
    let m = rt.manifest.clone();
    let node = rt.build_node_experts(&(0..16).collect::<Vec<_>>()).unwrap();
    let layer_cache_bytes = (m.n_kv_heads * m.max_seq * m.head_dim * 4) as u64;

    // Host-path state.
    let mut kc: Vec<HostTensor> = (0..m.n_layers).map(|_| rt.empty_layer_cache()).collect();
    let mut vc: Vec<HostTensor> = (0..m.n_layers).map(|_| rt.empty_layer_cache()).collect();
    // Device-path state (cache upload happens once, here).
    let mut st = DeviceState::new(&rt).unwrap();

    for (pos, tok) in [3u32, 99, 200, 7, 42].iter().enumerate() {
        // Reference step (host round trips).
        rt.take_transfer_stats();
        let mut x = rt.embed(*tok).unwrap();
        for l in 0..m.n_layers {
            let ar = rt.attn_router(l, &x, &kc[l], &vc[l], pos).unwrap();
            kc[l] = ar.k_cache.clone();
            vc[l] = ar.v_cache.clone();
            let ids: Vec<usize> =
                ar.top_i.iter().map(|&e| node.local_index(e).unwrap()).collect();
            let partial = rt
                .node_experts_direct(&node, l, &ar.moe_in, &ids, &ar.top_w)
                .unwrap();
            for (xi, (hi, ci)) in x.iter_mut().zip(ar.h.iter().zip(&partial)) {
                *xi = hi + ci;
            }
        }
        let want = rt.lm_head(&x).unwrap();
        let host_ts = rt.take_transfer_stats();

        // Device-resident step: same math, buffers stay put.
        st.begin_token(&rt, *tok).unwrap();
        for l in 0..m.n_layers {
            let (top_w, top_i) = st.attn_router(&rt, l, pos).unwrap();
            let ids: Vec<usize> =
                top_i.iter().map(|&e| node.local_index(e).unwrap()).collect();
            let partial = st.node_experts(&rt, &node, l, &ids, &top_w).unwrap();
            st.finish_layer_device(&rt, &partial).unwrap();
        }
        let got = st.logits(&rt).unwrap();
        let dev_ts = rt.take_transfer_stats();

        assert!(allclose(&got, &want, 1e-5), "logits diverge at pos {pos}");

        // The acceptance counter: the reference path round-trips every
        // cache both ways every layer; the device path must not move
        // even ONE cache's worth of bytes for the whole token.
        let host_bytes = host_ts.h2d_bytes + host_ts.d2h_bytes;
        let dev_bytes = dev_ts.h2d_bytes + dev_ts.d2h_bytes;
        assert!(
            host_bytes > 3 * m.n_layers as u64 * layer_cache_bytes,
            "host path moved only {host_bytes} B — meter broken?"
        );
        assert!(
            dev_bytes < layer_cache_bytes,
            "device path moved {dev_bytes} B (>= one {layer_cache_bytes} B cache)"
        );
    }
}

#[test]
fn padding_slots_change_nothing() {
    // LRU keep-warm runs carry weight 0 — numerics must be identical.
    let Some(dir) = artifacts_dir() else { return };
    let rt = NanoRuntime::load(&dir, false).unwrap();
    let ns = rt.manifest.num_slots;
    let node = rt.build_node_experts(&(0..8).collect::<Vec<_>>()).unwrap();
    let x = rt.embed(3).unwrap();
    let k = rt.empty_layer_cache();
    let v = rt.empty_layer_cache();
    let ar = rt.attn_router(0, &x, &k, &v, 0).unwrap();

    let mut idx = vec![0i32; ns];
    let mut w = vec![0f32; ns];
    let mut slot = 0;
    for (i, &e) in ar.top_i.iter().enumerate() {
        if let Some(local) = node.local_index(e) {
            idx[slot] = local as i32;
            w[slot] = ar.top_w[i];
            slot += 1;
        }
    }
    let a = rt.node_experts(&node, 0, &ar.moe_in, &idx, &w).unwrap();
    // Point the padding slots at a busy expert (weight stays 0).
    let mut idx2 = idx.clone();
    for s in slot..ns {
        idx2[s] = 7;
    }
    let b = rt.node_experts(&node, 0, &ar.moe_in, &idx2, &w).unwrap();
    assert_eq!(a, b);
}

/// Zero-weight dispatch skip (batched-dedup rider): an expert call
/// where NO slot carries weight must return exact zeros WITHOUT
/// dispatching an executable — the saved dispatches are visible in
/// `TransferStats::exec_calls`.
#[test]
fn zero_weight_dispatches_are_skipped() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = NanoRuntime::load(&dir, false).unwrap();
    let m = rt.manifest.clone();
    if m.max_batch < 2 {
        eprintln!("skipping: artifacts predate the dev_b* batched set");
        return;
    }
    let node = rt.build_node_experts(&(0..8).collect::<Vec<_>>()).unwrap();
    let ns = m.fast_num_slots;

    // Batched: no row routes to this node this iteration.
    let rows = 2;
    let moe_in = vec![0.1f32; rows * m.d_embed];
    rt.take_transfer_stats();
    let out = rt
        .node_experts_batched(&node, 0, rows, &moe_in, &vec![0i32; rows * ns], &vec![
            0f32;
            rows * ns
        ])
        .unwrap();
    let ts = rt.take_transfer_stats();
    assert!(out.iter().all(|&x| x == 0.0), "skip must return exact zeros");
    assert_eq!(ts.exec_calls, 0, "all-zero-weight batched dispatch not skipped");

    // One live slot: exactly ONE shared dispatch for the whole bucket.
    let mut w = vec![0f32; rows * ns];
    w[0] = 1.0;
    rt.node_experts_batched(&node, 0, rows, &moe_in, &vec![0i32; rows * ns], &w).unwrap();
    let ts = rt.take_transfer_stats();
    assert_eq!(ts.exec_calls, 1);

    // Serial direct path skips too.
    rt.take_transfer_stats();
    let out = rt
        .node_experts_direct(&node, 0, &moe_in[..m.d_embed], &vec![0usize; ns], &vec![0f32; ns])
        .unwrap();
    let ts = rt.take_transfer_stats();
    assert!(out.iter().all(|&x| x == 0.0));
    assert_eq!(ts.exec_calls, 0, "all-zero-weight direct dispatch not skipped");
}

/// Per-row expert dedup (batched decode): rows routing to the SAME
/// experts must produce partials numerically equivalent to the per-row
/// gathered/serial formulation (the dedup artifact slices each distinct
/// expert's weights once for the whole batch; only matmul reassociation
/// may differ, ~1 ulp — the live batched-vs-serial token-identity tests
/// in integration_cluster.rs pin it end to end).
#[test]
fn batched_dedup_matches_per_row_reference() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = NanoRuntime::load(&dir, false).unwrap();
    let m = rt.manifest.clone();
    if m.max_batch < 2 || !m.dedup_artifacts {
        eprintln!("skipping: artifacts predate the dedup set");
        return;
    }
    let node = rt.build_node_experts(&(0..8).collect::<Vec<_>>()).unwrap();
    let ns = m.fast_num_slots;
    let rows = 2;
    let mut moe_in = rt.embed(5).unwrap();
    moe_in.extend(rt.embed(17).unwrap());

    // Both rows reference the same 3 distinct experts (the dedup win
    // case; <= ns distinct, so the dedup executable takes the dispatch).
    let slot_idx: Vec<i32> = vec![1, 4, 6, 1, 4, 6, 1, 2];
    let slot_w: Vec<f32> = vec![0.4, 0.3, 0.3, 0.0, 0.5, 0.25, 0.25, 0.0];
    assert_eq!(slot_idx.len(), rows * ns);
    rt.take_transfer_stats();
    let got = rt.node_experts_batched(&node, 0, rows, &moe_in, &slot_idx, &slot_w).unwrap();
    let ts = rt.take_transfer_stats();
    assert_eq!(ts.exec_calls, 1, "dedup still costs exactly one shared dispatch");
    assert_eq!(got.len(), rows * m.d_embed);
    for r in 0..rows {
        let want = rt
            .node_experts_fast(
                &node,
                0,
                &moe_in[r * m.d_embed..(r + 1) * m.d_embed],
                &slot_idx[r * ns..(r + 1) * ns],
                &slot_w[r * ns..(r + 1) * ns],
            )
            .unwrap();
        assert!(
            allclose(&got[r * m.d_embed..(r + 1) * m.d_embed], &want, 1e-4),
            "dedup row {r} diverges from the per-row reference"
        );
    }
}

/// The PR 6 tentpole at the runtime layer: the on-device sampler roles
/// reproduce the host reference sampler token-for-token on real decode
/// logits — greedy and seeded top-k — while downloading 8 bytes per
/// draw instead of the `[1, V]` logits, and the stop role's on-device
/// membership compare matches the host's.
#[test]
fn serial_device_sampler_matches_host_reference_and_collapses_d2h() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = NanoRuntime::load(&dir, false).unwrap();
    if !rt.has_device_path() || !rt.has_sampler_path() {
        eprintln!("skipping: artifacts predate the dev_sample_* set");
        return;
    }
    let m = rt.manifest.clone();
    let node = rt.build_node_experts(&(0..16).collect::<Vec<_>>()).unwrap();
    let mut st = DeviceState::new(&rt).unwrap();

    let greedy = SamplingParams::greedy(8);
    let mut topk = SamplingParams::greedy(8);
    topk.sampler = Sampler::TopK { k: 8, temperature: 0.9 };
    topk.seed = 0xBEEF_CAFE;

    let mut tok = 3u32;
    for pos in 0..6 {
        st.begin_token(&rt, tok).unwrap();
        for l in 0..m.n_layers {
            let (top_w, top_i) = st.attn_router(&rt, l, pos).unwrap();
            let ids: Vec<usize> =
                top_i.iter().map(|&e| node.local_index(e).unwrap()).collect();
            let partial = st.node_experts(&rt, &node, l, &ids, &top_w).unwrap();
            st.finish_layer_device(&rt, &partial).unwrap();
        }
        // Reference: download the [1, V] logits, sample on the host at
        // draw counter pos + 1 (the sampled token's own position).
        rt.take_transfer_stats();
        let logits = st.logits(&rt).unwrap();
        let ts = rt.take_transfer_stats();
        assert_eq!(ts.d2h_bytes, 4 * m.vocab as u64, "logits download meter");
        let ctr = (pos + 1) as u32;
        let (want_g, want_glp) = greedy.sampler.sample_lp_at(&logits, greedy.seed, ctr);
        let (want_t, want_tlp) = topk.sampler.sample_lp_at(&logits, topk.seed, ctr);

        // Device: 8 bytes of packed (token, logprob) cross instead.
        rt.take_transfer_stats();
        let got_g =
            st.sample_on_device(&rt, &greedy.device_inputs(m.sampler_max_stop), pos).unwrap();
        let ts = rt.take_transfer_stats();
        assert_eq!(ts.d2h_bytes, 8, "greedy device sample must download 8 bytes");
        let got_t =
            st.sample_on_device(&rt, &topk.device_inputs(m.sampler_max_stop), pos).unwrap();

        assert_eq!(got_g.token, want_g, "greedy token diverges at pos {pos}");
        assert_eq!(got_t.token, want_t, "top-k token diverges at pos {pos}");
        // Host logprob accumulates in f64, device in f32: close, not bitwise.
        assert!((got_g.logprob - want_glp).abs() < 1e-3, "greedy logprob at pos {pos}");
        assert!((got_t.logprob - want_tlp).abs() < 1e-3, "top-k logprob at pos {pos}");
        assert!(!got_g.stop_hit && !got_t.stop_hit, "no stop set -> no stop hit");

        // Stop role: membership computed on device (+4 bytes of mask),
        // hit exactly when the sampled token is in the stop set.
        let mut with_stop = greedy.clone();
        with_stop.stop = vec![want_g];
        rt.take_transfer_stats();
        let hit =
            st.sample_on_device(&rt, &with_stop.device_inputs(m.sampler_max_stop), pos).unwrap();
        let ts = rt.take_transfer_stats();
        assert_eq!(ts.d2h_bytes, 12, "packed + stop mask download meter");
        assert!(hit.stop_hit && hit.token == want_g);
        let mut without = greedy.clone();
        without.stop = vec![want_g ^ 1];
        let miss =
            st.sample_on_device(&rt, &without.device_inputs(m.sampler_max_stop), pos).unwrap();
        assert!(!miss.stop_hit);

        tok = got_g.token;
    }
}
