//! Token-generation engine: sampling, requests, and the single-node
//! (dense) generation loop over the PJRT runtime. The multi-node loop
//! lives in `cluster::live` and shares `sampling`/`request`.

pub mod generation;
pub mod scheduler;
pub mod request;
pub mod sampling;

pub use generation::DenseEngine;
pub use scheduler::{serve_workload, SchedPolicy, SchedReport};
pub use request::{Request, RequestResult};
pub use sampling::Sampler;
